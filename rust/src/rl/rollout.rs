//! Vectorized parallel rollout engine (DESIGN.md §9).
//!
//! `Trainer::rollout` used to run episodes strictly sequentially: one fresh
//! `Env` per episode and one full ~500 KiB parameter sweep per single-state
//! `policy_fwd` call. This engine collects K episodes concurrently as
//! **lanes**: each scheduler iteration advances every in-flight lane by one
//! adaptation step, gathers the lanes that need a policy evaluation and
//! serves them with ONE `Workspace::policy_fwd_batch` call (one pass over
//! the parameter vector for the whole lane set — the §7 L1-reuse
//! discipline), then samples each lane's action from its own per-episode
//! PCG stream. Environment stepping — the simulator, the predictor, the
//! expert's IPA solve — is sharded across `std::thread` workers; the
//! forward and the sampling stay on the leader. Lanes refill from the
//! episode queue as they finish, so expert episodes (scored at episode
//! end, already batched) interleave with policy episodes exactly like the
//! sequential Algorithm 2 schedule.
//!
//! **Determinism contract** (extends §7/§8, pinned by
//! `rust/tests/rollout_vectorized.rs`): for fixed seeds the collected
//! trajectories are bitwise identical for ANY lane count and ANY worker
//! thread count, because
//!  * every episode's env is seeded `cfg.seed + episode` exactly as before
//!    (`Env::reset(seed)` ≡ fresh construction),
//!  * every episode samples from its own action stream
//!    `Pcg32::stream(episode_seed, ACTION_STREAM)` — no draw order is
//!    shared across episodes,
//!  * `policy_fwd_batch` rows are bitwise independent of the other rows in
//!    the batch (per-element accumulation chains fixed — §7), so which
//!    lanes happen to share a forward is unobservable,
//!  * the expert's switching hysteresis is reset per episode, and
//!  * results land in fixed per-episode buffer slots (episode order), not
//!    in completion order.

use crate::agents::{Agent, IpaAgent};
use crate::nn::spec::*;
use crate::nn::workspace::Workspace;
use crate::pipeline::TaskConfig;
use crate::rl::buffer::RolloutBuffer;
use crate::rl::trainer::logp_of_action;
use crate::sim::env::{
    build_masks_into, build_state_into, decode_action_into, encode_action_into, Env,
};
use crate::util::prng::Pcg32;

/// Sub-stream tag of the per-episode action-sampling RNG.
const ACTION_STREAM: u64 = 0x524f4c4c; // "ROLL"

/// One entry of the episode queue a wave collects.
#[derive(Clone, Copy, Debug)]
pub struct EpisodeSpec {
    /// 1-based episode number (drives logging and the expert schedule)
    pub episode: usize,
    /// environment + action-stream seed (`cfg.seed + episode`)
    pub seed: u64,
    /// expert-driven episode (Algorithm 2's every-f-th schedule)
    pub expert: bool,
}

/// Per-episode metadata of a collected wave; the transitions live in the
/// engine's per-slot [`RolloutBuffer`]s ([`RolloutEngine::buffer`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpisodeResult {
    pub episode: usize,
    pub expert: bool,
    pub mean_reward: f64,
    /// V(s_T) bootstrap for GAE (same numeric source as the trajectory)
    pub bootstrap: f64,
    pub steps: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// no episode assigned (queue exhausted)
    Idle,
    /// episode assigned, needs its first observation
    NeedObserve,
    /// state/masks staged, waiting for the leader's batched forward
    NeedForward,
    /// action staged, worker steps the env next
    ReadyToStep,
    /// env done, final state staged, waiting for leader finalization
    Finished,
}

/// One in-flight episode: env + buffers + the per-episode RNG stream.
struct Lane {
    env: Option<Env>,
    buf: RolloutBuffer,
    rng: Pcg32,
    expert_agent: IpaAgent,
    phase: Phase,
    episode: usize,
    /// index into the wave (fixed result/buffer slot)
    slot: usize,
    expert: bool,
    /// staged observation (state row + masks) and staged decision
    state: Vec<f32>,
    head_mask: Vec<bool>,
    task_mask: Vec<bool>,
    staged_idx: Vec<usize>,
    staged_logp: f32,
    staged_value: f32,
    action: Vec<TaskConfig>,
    reward_sum: f64,
    steps: usize,
    bootstrap: f64,
}

impl Lane {
    fn new() -> Self {
        Self {
            env: None,
            buf: RolloutBuffer::new(),
            rng: Pcg32::new(0),
            expert_agent: IpaAgent::new(),
            phase: Phase::Idle,
            episode: 0,
            slot: 0,
            expert: false,
            state: Vec::with_capacity(STATE_DIM),
            head_mask: Vec::with_capacity(LOGITS_DIM),
            task_mask: Vec::with_capacity(MAX_TASKS),
            staged_idx: vec![0; ACT_DIM],
            staged_logp: 0.0,
            staged_value: 0.0,
            action: Vec::new(),
            reward_sum: 0.0,
            steps: 0,
            bootstrap: 0.0,
        }
    }

    /// (Re)bind this lane to an episode: reset (or lazily build) the env,
    /// restart the action stream and the expert's hysteresis. `reuse_env`
    /// requires a seed-uniform factory (see [`RolloutEngine::reuse_envs`]).
    fn assign<F: FnMut(u64) -> Env>(
        &mut self,
        spec: &EpisodeSpec,
        slot: usize,
        factory: &mut F,
        reuse_env: bool,
    ) {
        match &mut self.env {
            Some(env) if reuse_env => env.reset(spec.seed),
            _ => self.env = Some(factory(spec.seed)),
        }
        self.rng = Pcg32::stream(spec.seed, ACTION_STREAM);
        self.expert_agent = IpaAgent::new();
        self.phase = Phase::NeedObserve;
        self.episode = spec.episode;
        self.slot = slot;
        self.expert = spec.expert;
        self.staged_idx.clear();
        self.staged_idx.resize(ACT_DIM, 0);
        self.staged_logp = 0.0;
        self.staged_value = 0.0;
        self.reward_sum = 0.0;
        self.steps = 0;
        self.bootstrap = 0.0;
    }
}

/// Worker-side advance: one adaptation step (when an action is staged) plus
/// the next observation. Touches only this lane — which worker runs it, and
/// in which order relative to other lanes, cannot change the result.
fn advance_lane(lane: &mut Lane) {
    if lane.phase == Phase::ReadyToStep {
        let r = lane.env.as_mut().expect("active lane has an env").step_lite(&lane.action);
        let tr = lane.buf.push_slot();
        tr.state.clear();
        tr.state.extend_from_slice(&lane.state);
        tr.action_idx.clear();
        tr.action_idx.extend_from_slice(&lane.staged_idx);
        tr.logp = lane.staged_logp;
        tr.value = lane.staged_value;
        tr.reward = r.reward;
        tr.head_mask.clear();
        tr.head_mask.extend_from_slice(&lane.head_mask);
        tr.task_mask.clear();
        tr.task_mask.extend_from_slice(&lane.task_mask);
        lane.reward_sum += r.reward;
        lane.steps += 1;
        if r.done {
            // stage the terminal state for the bootstrap / expert scoring
            let obs = lane.env.as_mut().expect("active lane has an env").observe();
            build_state_into(&obs, &mut lane.state);
            lane.phase = Phase::Finished;
            return;
        }
        lane.phase = Phase::NeedObserve;
    }
    if lane.phase == Phase::NeedObserve {
        let obs = lane.env.as_mut().expect("active lane has an env").observe();
        build_state_into(&obs, &mut lane.state);
        build_masks_into(obs.spec, &mut lane.head_mask, &mut lane.task_mask);
        if lane.expert {
            // expert action now (the IPA solve runs on the worker); its
            // logp/value under the current policy are filled by the batched
            // scoring pass at episode end
            let cfgs = lane.expert_agent.decide(&obs);
            encode_action_into(obs.spec, &cfgs, &mut lane.staged_idx);
            lane.action = cfgs;
            lane.staged_logp = 0.0;
            lane.staged_value = 0.0;
            lane.phase = Phase::ReadyToStep;
        } else {
            lane.phase = Phase::NeedForward;
        }
    }
}

/// The engine. Owns the lanes, the shared [`Workspace`], the per-slot
/// episode buffers and every piece of batching scratch; all of it is reused
/// across waves (`grow_events()` is the proof hook).
pub struct RolloutEngine {
    /// K — maximum concurrently in-flight episodes
    pub lanes_target: usize,
    /// env-stepping worker threads (0 = one per lane, capped by the host)
    pub threads: usize,
    /// refill lanes via in-place `Env::reset(seed)` instead of a fresh
    /// `env_factory(seed)` rebuild (the allocation-free path). Requires a
    /// **seed-uniform** factory: same spec / topology / workload kind /
    /// intervals for every seed, only the seed varying. A factory that
    /// derives e.g. the workload kind from the seed must turn this off —
    /// the engine cannot observe such dependence through a reset.
    pub reuse_envs: bool,
    lanes: Vec<Lane>,
    ws: Workspace,
    /// per-wave-slot episode buffers (episode order, fixed assignment)
    bufs: Vec<RolloutBuffer>,
    results: Vec<EpisodeResult>,
    /// stacked state rows of one scheduler iteration
    batch_states: Vec<f32>,
    /// (lane index, is_bootstrap_row) per stacked row
    batch_rows: Vec<(usize, bool)>,
    /// stacked states of one expert episode's scoring pass
    score_states: Vec<f32>,
    grow_events: u64,
}

impl RolloutEngine {
    pub fn new(lanes: usize, threads: usize) -> Self {
        Self {
            lanes_target: lanes.max(1),
            threads,
            reuse_envs: true,
            lanes: Vec::new(),
            ws: Workspace::new(),
            bufs: Vec::new(),
            results: Vec::new(),
            batch_states: Vec::new(),
            batch_rows: Vec::new(),
            score_states: Vec::new(),
            grow_events: 0,
        }
    }

    /// Total (re)allocation count across the engine's own machinery: the
    /// shared workspace, the lane/transition pools and the batching scratch.
    /// Flat after the first wave at a steady episode shape — the
    /// alloc-free-rollout proof hook (`perf_rollout` and the determinism
    /// tests assert on it). Environment-internal transients (observation
    /// assembly, the cluster store's apply) are outside this counter; see
    /// DESIGN.md §9.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
            + self.ws.grow_events()
            + self.bufs.iter().map(|b| b.grow_events()).sum::<u64>()
            + self.lanes.iter().map(|l| l.buf.grow_events()).sum::<u64>()
    }

    /// Per-episode metadata of the most recent wave, in episode order.
    pub fn results(&self) -> &[EpisodeResult] {
        &self.results
    }

    /// Transitions of wave slot `i` (matching `results()[i]`).
    pub fn buffer(&self, i: usize) -> &RolloutBuffer {
        &self.bufs[i]
    }

    /// Collect every episode of `wave` under frozen `params`, K lanes at a
    /// time. Returns when all episodes are finalized; read them back via
    /// [`RolloutEngine::results`] / [`RolloutEngine::buffer`].
    pub fn collect_wave<F: FnMut(u64) -> Env>(
        &mut self,
        params: &[f32],
        wave: &[EpisodeSpec],
        factory: &mut F,
    ) {
        assert!(!wave.is_empty(), "collect_wave: empty wave");
        if self.bufs.len() < wave.len() {
            self.grow_events += 1;
            self.bufs.resize_with(wave.len(), RolloutBuffer::new);
        }
        for b in self.bufs.iter_mut().take(wave.len()) {
            b.recycle();
        }
        if self.results.capacity() < wave.len() {
            self.grow_events += 1;
        }
        self.results.clear();
        self.results.resize(wave.len(), EpisodeResult::default());

        let lanes_n = self.lanes_target.min(wave.len());
        while self.lanes.len() < lanes_n {
            self.grow_events += 1;
            self.lanes.push(Lane::new());
        }
        if self.batch_states.capacity() < lanes_n * STATE_DIM {
            self.grow_events += 1;
            self.batch_states.reserve(lanes_n * STATE_DIM - self.batch_states.len());
        }
        if self.batch_rows.capacity() < lanes_n {
            self.grow_events += 1;
            self.batch_rows.reserve(lanes_n - self.batch_rows.len());
        }
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
        .clamp(1, lanes_n);

        let reuse_envs = self.reuse_envs;
        let mut next = 0usize;
        for lane in self.lanes.iter_mut().take(lanes_n) {
            if next < wave.len() {
                lane.assign(&wave[next], next, factory, reuse_envs);
                next += 1;
            } else {
                lane.phase = Phase::Idle;
            }
        }
        // lanes beyond the wave's needs sit out this wave entirely
        for lane in self.lanes.iter_mut().skip(lanes_n) {
            lane.phase = Phase::Idle;
        }

        loop {
            let Self {
                lanes,
                ws,
                bufs,
                results,
                batch_states,
                batch_rows,
                score_states,
                grow_events,
                ..
            } = self;
            let lanes = &mut lanes[..lanes_n];
            if lanes.iter().all(|l| l.phase == Phase::Idle) {
                break;
            }

            // ---- worker phase: step + observe, sharded across threads ----
            if threads == 1 {
                for lane in lanes.iter_mut() {
                    if lane.phase != Phase::Idle {
                        advance_lane(lane);
                    }
                }
            } else {
                // one spawn per worker per scheduler iteration: ~tens of µs
                // of spawn/join overhead, second-order next to the batched
                // forward this buys (a persistent per-wave worker pool with
                // lane-ownership ping-pong is the ROADMAP follow-up)
                let per = lanes.len().div_ceil(threads);
                std::thread::scope(|sc| {
                    for chunk in lanes.chunks_mut(per) {
                        sc.spawn(move || {
                            for lane in chunk {
                                if lane.phase != Phase::Idle {
                                    advance_lane(lane);
                                }
                            }
                        });
                    }
                });
            }

            // ---- leader phase 1: one ragged batched forward ----
            // rows: in-flight policy lanes wanting an action + finished
            // policy lanes' terminal states (their GAE bootstrap)
            batch_states.clear();
            batch_rows.clear();
            for (li, lane) in lanes.iter().enumerate() {
                match lane.phase {
                    Phase::NeedForward => {
                        batch_states.extend_from_slice(&lane.state);
                        batch_rows.push((li, false));
                    }
                    Phase::Finished if !lane.expert => {
                        batch_states.extend_from_slice(&lane.state);
                        batch_rows.push((li, true));
                    }
                    _ => {}
                }
            }
            if !batch_rows.is_empty() {
                let _ = ws.policy_fwd_batch(params, batch_states, batch_rows.len());
                for (row, &(li, is_bootstrap)) in batch_rows.iter().enumerate() {
                    let lane = &mut lanes[li];
                    if is_bootstrap {
                        lane.bootstrap = ws.value_at(row) as f64;
                    } else {
                        lane.staged_logp = ws.sample_row(
                            row,
                            &lane.head_mask,
                            &lane.task_mask,
                            false,
                            &mut lane.rng,
                            &mut lane.staged_idx,
                        );
                        lane.staged_value = ws.value_at(row);
                        let env = lane.env.as_ref().expect("active lane has an env");
                        decode_action_into(&env.spec, &lane.staged_idx, &mut lane.action);
                        lane.phase = Phase::ReadyToStep;
                    }
                }
            }

            // ---- leader phase 2: finalize finished episodes, refill ----
            for lane in lanes.iter_mut() {
                if lane.phase != Phase::Finished {
                    continue;
                }
                if lane.expert {
                    // count scoring-scratch growth (a longer expert episode
                    // than any seen before) so grow_events() keeps its
                    // "covers every engine buffer" promise
                    if score_states.capacity() < (lane.buf.len() + 1) * STATE_DIM {
                        *grow_events += 1;
                    }
                    lane.bootstrap =
                        score_expert_episode(ws, params, &mut lane.buf, &lane.state, score_states)
                            as f64;
                }
                results[lane.slot] = EpisodeResult {
                    episode: lane.episode,
                    expert: lane.expert,
                    mean_reward: lane.reward_sum / (lane.steps as f64).max(1.0),
                    bootstrap: lane.bootstrap,
                    steps: lane.steps,
                };
                std::mem::swap(&mut lane.buf, &mut bufs[lane.slot]);
                if next < wave.len() {
                    lane.assign(&wave[next], next, factory, reuse_envs);
                    next += 1;
                } else {
                    lane.phase = Phase::Idle;
                }
            }
        }
    }
}

/// Score every expert transition of a finished episode — plus the terminal
/// bootstrap state — under the current policy in ONE batched forward
/// (Algorithm 2 needs log π(a_expert | s) and V(s) for the replay memory;
/// the expert's actions don't depend on the policy outputs, so scoring
/// defers to episode end and batches instead of running one forward per
/// step). Returns V(s_T) so the GAE bootstrap shares the episode's numeric
/// source.
fn score_expert_episode(
    ws: &mut Workspace,
    params: &[f32],
    buf: &mut RolloutBuffer,
    final_state: &[f32],
    score_states: &mut Vec<f32>,
) -> f32 {
    let batch = buf.len() + 1;
    score_states.clear();
    for tr in &buf.transitions {
        score_states.extend_from_slice(&tr.state);
    }
    score_states.extend_from_slice(final_state);
    let (logits, values) = ws.policy_fwd_batch(params, score_states, batch);
    for (i, tr) in buf.transitions.iter_mut().enumerate() {
        let row = &logits[i * LOGITS_DIM..(i + 1) * LOGITS_DIM];
        tr.logp = logp_of_action(row, &tr.head_mask, &tr.task_mask, &tr.action_idx);
        tr.value = values[i];
    }
    values[batch - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterTopology;
    use crate::pipeline::{catalog, QosWeights};
    use crate::workload::predictor::MovingMaxPredictor;
    use crate::workload::WorkloadKind;

    fn factory(seed: u64) -> Env {
        Env::from_workload(
            catalog::by_name("P1").unwrap().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            WorkloadKind::Fluctuating,
            seed,
            Box::new(MovingMaxPredictor::default()),
            10,
            100,
            3.0,
        )
    }

    fn small_params(seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
    }

    fn wave(n: usize, base_seed: u64, expert_freq: usize) -> Vec<EpisodeSpec> {
        (1..=n)
            .map(|episode| EpisodeSpec {
                episode,
                seed: base_seed + episode as u64,
                expert: expert_freq > 0 && episode % expert_freq == 0,
            })
            .collect()
    }

    #[test]
    fn collects_every_episode_with_full_trajectories() {
        let params = small_params(1);
        let mut eng = RolloutEngine::new(3, 1);
        let w = wave(5, 42, 2);
        eng.collect_wave(&params, &w, &mut factory);
        assert_eq!(eng.results().len(), 5);
        for (i, r) in eng.results().iter().enumerate() {
            assert_eq!(r.episode, i + 1, "results in episode order");
            assert_eq!(r.expert, (i + 1) % 2 == 0);
            assert_eq!(r.steps, 10, "100 s cycle / 10 s interval");
            assert_eq!(eng.buffer(i).len(), 10);
            assert!(r.mean_reward.is_finite() && r.bootstrap.is_finite());
            for tr in &eng.buffer(i).transitions {
                assert_eq!(tr.state.len(), STATE_DIM);
                assert_eq!(tr.action_idx.len(), ACT_DIM);
                assert!(tr.value.is_finite());
            }
            if !r.expert {
                // sampled actions must carry their (negative) log-probs
                assert!(eng.buffer(i).transitions.iter().all(|t| t.logp < 0.0));
            }
        }
    }

    #[test]
    fn more_lanes_than_episodes_is_fine() {
        let params = small_params(2);
        let mut eng = RolloutEngine::new(8, 2);
        let w = wave(2, 7, 0);
        eng.collect_wave(&params, &w, &mut factory);
        assert_eq!(eng.results().len(), 2);
        assert!(eng.results().iter().all(|r| r.steps == 10));
    }

    #[test]
    fn engine_reuse_across_waves_is_allocation_free() {
        let params = small_params(3);
        let mut eng = RolloutEngine::new(2, 2);
        let w = wave(4, 11, 2);
        eng.collect_wave(&params, &w, &mut factory);
        let warm = eng.grow_events();
        for round in 0..3 {
            let w = wave(4, 100 + round, 2);
            eng.collect_wave(&params, &w, &mut factory);
            assert_eq!(eng.grow_events(), warm, "wave {round} must reuse warm buffers");
        }
    }
}
