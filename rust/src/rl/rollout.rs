//! Vectorized parallel rollout engine (DESIGN.md §9).
//!
//! `Trainer::rollout` used to run episodes strictly sequentially: one fresh
//! `Env` per episode and one full ~500 KiB parameter sweep per single-state
//! `policy_fwd` call. This engine collects K episodes concurrently as
//! **lanes**: each scheduler iteration advances every in-flight lane by one
//! adaptation step, gathers the lanes that need a policy evaluation and
//! serves them with ONE `Workspace::policy_fwd_batch` call (one pass over
//! the parameter vector for the whole lane set — the §7 L1-reuse
//! discipline), then samples each lane's action from its own per-episode
//! PCG stream. Environment stepping — the simulator, the predictor, the
//! expert's IPA solve — is sharded across a **persistent worker pool**:
//! long-lived threads fed by channel ping-pong of owned lane chunks (the
//! per-iteration `std::thread::scope` spawns this replaced cost ~tens of
//! µs each); the forward and the sampling stay on the leader. Lanes refill
//! from the episode queue as they finish, so expert episodes (scored at
//! episode end, already batched) interleave with policy episodes exactly
//! like the sequential Algorithm 2 schedule.
//!
//! **Determinism contract** (extends §7/§8, pinned by
//! `rust/tests/rollout_vectorized.rs`): for fixed seeds the collected
//! trajectories are bitwise identical for ANY lane count and ANY worker
//! thread count, because
//!  * every episode's env is seeded `cfg.seed + episode` exactly as before
//!    (`Env::reset(seed)` ≡ fresh construction),
//!  * every episode samples from its own action stream
//!    `Pcg32::stream(episode_seed, ACTION_STREAM)` — no draw order is
//!    shared across episodes,
//!  * `policy_fwd_batch` rows are bitwise independent of the other rows in
//!    the batch (per-element accumulation chains fixed by the §14 lane
//!    kernels, batch-invariant by construction — §7), so which
//!    lanes happen to share a forward is unobservable,
//!  * the expert's switching hysteresis is reset per episode, and
//!  * results land in fixed per-episode buffer slots (episode order), not
//!    in completion order.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::agents::IpaAgent;
use crate::nn::spec::*;
use crate::nn::workspace::Workspace;
use crate::pipeline::TaskConfig;
use crate::rl::buffer::RolloutBuffer;
use crate::rl::trainer::logp_of_action;
use crate::sim::env::{
    build_masks_into, build_state_into, decode_action_into, encode_action_into, Env,
};
use crate::util::prng::Pcg32;

/// Sub-stream tag of the per-episode action-sampling RNG.
const ACTION_STREAM: u64 = 0x524f4c4c; // "ROLL"

/// One entry of the episode queue a wave collects.
#[derive(Clone, Copy, Debug)]
pub struct EpisodeSpec {
    /// 1-based episode number (drives logging and the expert schedule)
    pub episode: usize,
    /// environment + action-stream seed (`cfg.seed + episode`)
    pub seed: u64,
    /// expert-driven episode (Algorithm 2's every-f-th schedule)
    pub expert: bool,
}

/// Per-episode metadata of a collected wave; the transitions live in the
/// engine's per-slot [`RolloutBuffer`]s ([`RolloutEngine::buffer`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpisodeResult {
    pub episode: usize,
    pub expert: bool,
    pub mean_reward: f64,
    /// V(s_T) bootstrap for GAE (same numeric source as the trajectory)
    pub bootstrap: f64,
    pub steps: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// no episode assigned (queue exhausted)
    Idle,
    /// episode assigned, needs its first observation
    NeedObserve,
    /// state/masks staged, waiting for the leader's batched forward
    NeedForward,
    /// action staged, worker steps the env next
    ReadyToStep,
    /// env done, final state staged, waiting for leader finalization
    Finished,
}

/// One in-flight episode: env + buffers + the per-episode RNG stream.
struct Lane {
    env: Option<Env>,
    buf: RolloutBuffer,
    rng: Pcg32,
    expert_agent: IpaAgent,
    phase: Phase,
    episode: usize,
    /// index into the wave (fixed result/buffer slot)
    slot: usize,
    expert: bool,
    /// staged observation (state row + masks) and staged decision
    state: Vec<f32>,
    head_mask: Vec<bool>,
    task_mask: Vec<bool>,
    staged_idx: Vec<usize>,
    staged_logp: f32,
    staged_value: f32,
    action: Vec<TaskConfig>,
    reward_sum: f64,
    steps: usize,
    bootstrap: f64,
}

impl Lane {
    fn new() -> Self {
        Self {
            env: None,
            buf: RolloutBuffer::new(),
            rng: Pcg32::new(0),
            expert_agent: IpaAgent::new(),
            phase: Phase::Idle,
            episode: 0,
            slot: 0,
            expert: false,
            state: Vec::with_capacity(STATE_DIM),
            head_mask: Vec::with_capacity(LOGITS_DIM),
            task_mask: Vec::with_capacity(MAX_TASKS),
            staged_idx: vec![0; ACT_DIM],
            staged_logp: 0.0,
            staged_value: 0.0,
            action: Vec::new(),
            reward_sum: 0.0,
            steps: 0,
            bootstrap: 0.0,
        }
    }

    /// (Re)bind this lane to an episode: reset (or lazily build) the env,
    /// restart the action stream and the expert's hysteresis (the solver's
    /// scratch and pure memo caches survive — DESIGN.md §10). `reuse_env`
    /// requires a seed-uniform factory (see [`RolloutEngine::reuse_envs`]).
    fn assign<F: FnMut(u64) -> Env>(
        &mut self,
        spec: &EpisodeSpec,
        slot: usize,
        factory: &mut F,
        reuse_env: bool,
        expert_exhaustive: bool,
    ) {
        match &mut self.env {
            Some(env) if reuse_env => env.reset(spec.seed),
            _ => self.env = Some(factory(spec.seed)),
        }
        self.rng = Pcg32::stream(spec.seed, ACTION_STREAM);
        self.expert_agent.reset_episode();
        self.expert_agent.solver.exhaustive = expert_exhaustive;
        self.phase = Phase::NeedObserve;
        self.episode = spec.episode;
        self.slot = slot;
        self.expert = spec.expert;
        self.staged_idx.clear();
        self.staged_idx.resize(ACT_DIM, 0);
        self.staged_logp = 0.0;
        self.staged_value = 0.0;
        self.reward_sum = 0.0;
        self.steps = 0;
        self.bootstrap = 0.0;
    }
}

/// Worker-side advance: one adaptation step (when an action is staged) plus
/// the next observation. Touches only this lane — which worker runs it, and
/// in which order relative to other lanes, cannot change the result.
fn advance_lane(lane: &mut Lane) {
    if lane.phase == Phase::ReadyToStep {
        let r = lane.env.as_mut().expect("active lane has an env").step_lite(&lane.action);
        let tr = lane.buf.push_slot();
        tr.state.clear();
        tr.state.extend_from_slice(&lane.state);
        tr.action_idx.clear();
        tr.action_idx.extend_from_slice(&lane.staged_idx);
        tr.logp = lane.staged_logp;
        tr.value = lane.staged_value;
        tr.reward = r.reward;
        tr.head_mask.clear();
        tr.head_mask.extend_from_slice(&lane.head_mask);
        tr.task_mask.clear();
        tr.task_mask.extend_from_slice(&lane.task_mask);
        lane.reward_sum += r.reward;
        lane.steps += 1;
        if r.done {
            // stage the terminal state for the bootstrap / expert scoring
            let obs = lane.env.as_mut().expect("active lane has an env").observe();
            build_state_into(&obs, &mut lane.state);
            lane.phase = Phase::Finished;
            return;
        }
        lane.phase = Phase::NeedObserve;
    }
    if lane.phase == Phase::NeedObserve {
        let obs = lane.env.as_mut().expect("active lane has an env").observe();
        build_state_into(&obs, &mut lane.state);
        build_masks_into(obs.spec, &mut lane.head_mask, &mut lane.task_mask);
        if lane.expert {
            // expert action now (the IPA solve runs on the worker, straight
            // into the lane's reused action vec); its logp/value under the
            // current policy are filled by the batched scoring pass at
            // episode end
            lane.expert_agent.decide_into(&obs, &mut lane.action);
            encode_action_into(obs.spec, &lane.action, &mut lane.staged_idx);
            lane.staged_logp = 0.0;
            lane.staged_value = 0.0;
            lane.phase = Phase::ReadyToStep;
        } else {
            lane.phase = Phase::NeedForward;
        }
    }
}

/// One chunk of lanes shipped to a worker and back (ownership ping-pong).
struct Job {
    /// offset of the chunk's first lane in the engine's lane vector
    start: usize,
    /// a worker panic is carried back (payload intact) instead of wedging
    /// the leader; the leader re-raises it via `resume_unwind`, so failures
    /// diagnose identically to the single-threaded path
    panic: Option<Box<dyn std::any::Any + Send>>,
    lanes: Vec<Lane>,
}

/// Persistent env-stepping worker pool (DESIGN.md §9): long-lived threads
/// fed by channel ping-pong of owned lane chunks, replacing the former
/// per-scheduler-iteration `std::thread::scope` spawns (~tens of µs of
/// spawn/join overhead each). Which worker advances which lanes is
/// unobservable — lanes are independent and land back in their original
/// slots — so the pool preserves the engine's bitwise determinism contract
/// for any pool size.
struct WorkerPool {
    job_txs: Vec<Sender<Job>>,
    done_rx: Receiver<Job>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    fn new(workers: usize) -> Self {
        let (done_tx, done_rx) = channel::<Job>();
        let mut job_txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                while let Ok(mut job) = rx.recv() {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        for lane in job.lanes.iter_mut() {
                            if lane.phase != Phase::Idle {
                                advance_lane(lane);
                            }
                        }
                    }));
                    job.panic = result.err();
                    if done.send(job).is_err() {
                        break; // leader gone
                    }
                }
            }));
            job_txs.push(tx);
        }
        Self { job_txs, done_rx, handles }
    }

    fn size(&self) -> usize {
        self.job_txs.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.job_txs.clear(); // closing the job channels stops the workers
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The engine. Owns the lanes, the shared [`Workspace`], the persistent
/// worker pool, the per-slot episode buffers and every piece of batching
/// scratch; all of it is reused across waves (`grow_events()` is the proof
/// hook).
pub struct RolloutEngine {
    /// K — maximum concurrently in-flight episodes
    pub lanes_target: usize,
    /// env-stepping worker threads (0 = one per lane, capped by the host)
    pub threads: usize,
    /// refill lanes via in-place `Env::reset(seed)` instead of a fresh
    /// `env_factory(seed)` rebuild (the allocation-free path). Requires a
    /// **seed-uniform** factory: same spec / topology / workload kind /
    /// intervals for every seed, only the seed varying. A factory that
    /// derives e.g. the workload kind from the seed must turn this off —
    /// the engine cannot observe such dependence through a reset.
    pub reuse_envs: bool,
    /// run expert lanes through the exhaustive reference IPA solver instead
    /// of the branch-and-bound fast path — the equivalence tests pin that
    /// flipping this changes nothing (DESIGN.md §10).
    pub expert_exhaustive: bool,
    lanes: Vec<Lane>,
    pool: Option<WorkerPool>,
    /// recycled chunk vectors for the lane ping-pong
    chunk_shells: Vec<Vec<Lane>>,
    /// reassembly scratch for returned jobs (sorted by chunk offset)
    returned: Vec<Job>,
    ws: Workspace,
    /// per-wave-slot episode buffers (episode order, fixed assignment)
    bufs: Vec<RolloutBuffer>,
    results: Vec<EpisodeResult>,
    /// stacked state rows of one scheduler iteration
    batch_states: Vec<f32>,
    /// (lane index, is_bootstrap_row) per stacked row
    batch_rows: Vec<(usize, bool)>,
    /// stacked states of one expert episode's scoring pass
    score_states: Vec<f32>,
    grow_events: u64,
}

impl RolloutEngine {
    pub fn new(lanes: usize, threads: usize) -> Self {
        Self {
            lanes_target: lanes.max(1),
            threads,
            reuse_envs: true,
            expert_exhaustive: false,
            lanes: Vec::new(),
            pool: None,
            chunk_shells: Vec::new(),
            returned: Vec::new(),
            ws: Workspace::new(),
            bufs: Vec::new(),
            results: Vec::new(),
            batch_states: Vec::new(),
            batch_rows: Vec::new(),
            score_states: Vec::new(),
            grow_events: 0,
        }
    }

    /// Total (re)allocation count across the engine's own machinery: the
    /// shared workspace, the lane/transition pools, the batching scratch
    /// and the worker-pool chunk shells. Flat after the first wave at a
    /// steady episode shape — the alloc-free-rollout proof hook
    /// (`perf_rollout` and the determinism tests assert on it). Channel
    /// node allocations inside `std::sync::mpsc`, environment-internal
    /// transients (the cluster store's apply) and the expert solver's memo
    /// rings are outside this counter — the solver carries its own
    /// `IpaSolver::grow_events`, asserted flat by `perf_ipa`; see
    /// DESIGN.md §9/§10.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
            + self.ws.grow_events()
            + self.bufs.iter().map(|b| b.grow_events()).sum::<u64>()
            + self.lanes.iter().map(|l| l.buf.grow_events()).sum::<u64>()
    }

    /// Per-episode metadata of the most recent wave, in episode order.
    pub fn results(&self) -> &[EpisodeResult] {
        &self.results
    }

    /// Transitions of wave slot `i` (matching `results()[i]`).
    pub fn buffer(&self, i: usize) -> &RolloutBuffer {
        &self.bufs[i]
    }

    /// Collect every episode of `wave` under frozen `params`, K lanes at a
    /// time. Returns when all episodes are finalized; read them back via
    /// [`RolloutEngine::results`] / [`RolloutEngine::buffer`].
    pub fn collect_wave<F: FnMut(u64) -> Env>(
        &mut self,
        params: &[f32],
        wave: &[EpisodeSpec],
        factory: &mut F,
    ) {
        assert!(!wave.is_empty(), "collect_wave: empty wave");
        if self.bufs.len() < wave.len() {
            self.grow_events += 1;
            self.bufs.resize_with(wave.len(), RolloutBuffer::new);
        }
        for b in self.bufs.iter_mut().take(wave.len()) {
            b.recycle();
        }
        if self.results.capacity() < wave.len() {
            self.grow_events += 1;
        }
        self.results.clear();
        self.results.resize(wave.len(), EpisodeResult::default());

        let lanes_n = self.lanes_target.min(wave.len());
        while self.lanes.len() < lanes_n {
            self.grow_events += 1;
            self.lanes.push(Lane::new());
        }
        if self.batch_states.capacity() < lanes_n * STATE_DIM {
            self.grow_events += 1;
            self.batch_states.reserve(lanes_n * STATE_DIM - self.batch_states.len());
        }
        if self.batch_rows.capacity() < lanes_n {
            self.grow_events += 1;
            self.batch_rows.reserve(lanes_n - self.batch_rows.len());
        }
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
        .clamp(1, lanes_n);
        if threads > 1 {
            self.ensure_pool(threads);
        }

        let reuse_envs = self.reuse_envs;
        let expert_exhaustive = self.expert_exhaustive;
        let mut next = 0usize;
        for lane in self.lanes.iter_mut().take(lanes_n) {
            if next < wave.len() {
                lane.assign(&wave[next], next, factory, reuse_envs, expert_exhaustive);
                next += 1;
            } else {
                lane.phase = Phase::Idle;
            }
        }
        // lanes beyond the wave's needs sit out this wave entirely
        for lane in self.lanes.iter_mut().skip(lanes_n) {
            lane.phase = Phase::Idle;
        }

        loop {
            if self.lanes[..lanes_n].iter().all(|l| l.phase == Phase::Idle) {
                break;
            }

            // ---- worker phase: step + observe, sharded over the pool ----
            if threads == 1 {
                for lane in self.lanes[..lanes_n].iter_mut() {
                    if lane.phase != Phase::Idle {
                        advance_lane(lane);
                    }
                }
            } else {
                self.run_worker_phase(threads, lanes_n);
            }

            let Self {
                lanes,
                ws,
                bufs,
                results,
                batch_states,
                batch_rows,
                score_states,
                grow_events,
                ..
            } = self;
            let lanes = &mut lanes[..lanes_n];

            // ---- leader phase 1: one ragged batched forward ----
            // rows: in-flight policy lanes wanting an action + finished
            // policy lanes' terminal states (their GAE bootstrap)
            batch_states.clear();
            batch_rows.clear();
            for (li, lane) in lanes.iter().enumerate() {
                match lane.phase {
                    Phase::NeedForward => {
                        batch_states.extend_from_slice(&lane.state);
                        batch_rows.push((li, false));
                    }
                    Phase::Finished if !lane.expert => {
                        batch_states.extend_from_slice(&lane.state);
                        batch_rows.push((li, true));
                    }
                    _ => {}
                }
            }
            if !batch_rows.is_empty() {
                let _ = ws.policy_fwd_batch(params, batch_states, batch_rows.len());
                for (row, &(li, is_bootstrap)) in batch_rows.iter().enumerate() {
                    let lane = &mut lanes[li];
                    if is_bootstrap {
                        lane.bootstrap = ws.value_at(row) as f64;
                    } else {
                        lane.staged_logp = ws.sample_row(
                            row,
                            &lane.head_mask,
                            &lane.task_mask,
                            false,
                            &mut lane.rng,
                            &mut lane.staged_idx,
                        );
                        lane.staged_value = ws.value_at(row);
                        let env = lane.env.as_ref().expect("active lane has an env");
                        decode_action_into(&env.spec, &lane.staged_idx, &mut lane.action);
                        lane.phase = Phase::ReadyToStep;
                    }
                }
            }

            // ---- leader phase 2: finalize finished episodes, refill ----
            for lane in lanes.iter_mut() {
                if lane.phase != Phase::Finished {
                    continue;
                }
                if lane.expert {
                    // count scoring-scratch growth (a longer expert episode
                    // than any seen before) so grow_events() keeps its
                    // "covers every engine buffer" promise
                    if score_states.capacity() < (lane.buf.len() + 1) * STATE_DIM {
                        *grow_events += 1;
                    }
                    lane.bootstrap =
                        score_expert_episode(ws, params, &mut lane.buf, &lane.state, score_states)
                            as f64;
                }
                results[lane.slot] = EpisodeResult {
                    episode: lane.episode,
                    expert: lane.expert,
                    mean_reward: lane.reward_sum / (lane.steps as f64).max(1.0),
                    bootstrap: lane.bootstrap,
                    steps: lane.steps,
                };
                std::mem::swap(&mut lane.buf, &mut bufs[lane.slot]);
                if next < wave.len() {
                    lane.assign(&wave[next], next, factory, reuse_envs, expert_exhaustive);
                    next += 1;
                } else {
                    lane.phase = Phase::Idle;
                }
            }
        }
    }

    /// (Re)build the persistent worker pool when the resolved thread count
    /// changes; a pool survives across waves, so steady training pays the
    /// thread/channel setup exactly once.
    fn ensure_pool(&mut self, threads: usize) {
        if self.pool.as_ref().map(WorkerPool::size) == Some(threads) {
            return;
        }
        self.grow_events += 1; // counted one-off: threads, channels, scratch
        self.pool = Some(WorkerPool::new(threads));
        if self.chunk_shells.capacity() < threads {
            let len = self.chunk_shells.len();
            self.chunk_shells.reserve(threads - len);
        }
        if self.returned.capacity() < threads {
            let len = self.returned.len();
            self.returned.reserve(threads - len);
        }
    }

    /// Ship every lane to the persistent workers in contiguous chunks and
    /// splice the advanced lanes back into their slots. Chunk sizing is
    /// driven by the wave's ACTIVE lane count so a tail wave stays balanced
    /// across workers; stale idle lanes beyond it ride along with the last
    /// chunk (workers skip `Idle` in O(1)). Chunks drain tail-first so each
    /// `drain(start..)` is O(chunk) with no element shifting; reassembly
    /// sorts the (≤ threads) returned jobs by chunk offset, so lane order —
    /// and therefore every buffer/result slot — is exactly what the
    /// sequential path produces.
    fn run_worker_phase(&mut self, threads: usize, lanes_n: usize) {
        let per = lanes_n.div_ceil(threads);
        let n_chunks = lanes_n.div_ceil(per);
        let mut sent = 0usize;
        for chunk in (0..n_chunks).rev() {
            let start = chunk * per;
            let mut shell = self.chunk_shells.pop().unwrap_or_default();
            if shell.capacity() < self.lanes.len() - start {
                self.grow_events += 1;
            }
            shell.extend(self.lanes.drain(start..));
            let pool = self.pool.as_ref().expect("pool built before the wave");
            pool.job_txs[chunk % pool.size()]
                .send(Job { start, panic: None, lanes: shell })
                .expect("rollout worker alive");
            sent += 1;
        }
        debug_assert!(self.lanes.is_empty());
        self.returned.clear();
        for _ in 0..sent {
            let pool = self.pool.as_ref().expect("pool built before the wave");
            let mut job = pool.done_rx.recv().expect("rollout worker alive");
            if let Some(payload) = job.panic.take() {
                std::panic::resume_unwind(payload);
            }
            self.returned.push(job);
        }
        self.returned.sort_unstable_by_key(|j| j.start);
        for mut job in self.returned.drain(..) {
            self.lanes.append(&mut job.lanes);
            self.chunk_shells.push(job.lanes);
        }
    }
}

/// Score every expert transition of a finished episode — plus the terminal
/// bootstrap state — under the current policy in ONE batched forward
/// (Algorithm 2 needs log π(a_expert | s) and V(s) for the replay memory;
/// the expert's actions don't depend on the policy outputs, so scoring
/// defers to episode end and batches instead of running one forward per
/// step). Returns V(s_T) so the GAE bootstrap shares the episode's numeric
/// source.
fn score_expert_episode(
    ws: &mut Workspace,
    params: &[f32],
    buf: &mut RolloutBuffer,
    final_state: &[f32],
    score_states: &mut Vec<f32>,
) -> f32 {
    let batch = buf.len() + 1;
    score_states.clear();
    for tr in &buf.transitions {
        score_states.extend_from_slice(&tr.state);
    }
    score_states.extend_from_slice(final_state);
    let (logits, values) = ws.policy_fwd_batch(params, score_states, batch);
    for (i, tr) in buf.transitions.iter_mut().enumerate() {
        let row = &logits[i * LOGITS_DIM..(i + 1) * LOGITS_DIM];
        tr.logp = logp_of_action(row, &tr.head_mask, &tr.task_mask, &tr.action_idx);
        tr.value = values[i];
    }
    values[batch - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterTopology;
    use crate::pipeline::{catalog, QosWeights};
    use crate::workload::predictor::MovingMaxPredictor;
    use crate::workload::WorkloadKind;

    fn factory(seed: u64) -> Env {
        Env::from_workload(
            catalog::by_name("P1").unwrap().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            WorkloadKind::Fluctuating,
            seed,
            Box::new(MovingMaxPredictor::default()),
            10,
            100,
            3.0,
        )
    }

    fn small_params(seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
    }

    fn wave(n: usize, base_seed: u64, expert_freq: usize) -> Vec<EpisodeSpec> {
        (1..=n)
            .map(|episode| EpisodeSpec {
                episode,
                seed: base_seed + episode as u64,
                expert: expert_freq > 0 && episode % expert_freq == 0,
            })
            .collect()
    }

    #[test]
    fn collects_every_episode_with_full_trajectories() {
        let params = small_params(1);
        let mut eng = RolloutEngine::new(3, 1);
        let w = wave(5, 42, 2);
        eng.collect_wave(&params, &w, &mut factory);
        assert_eq!(eng.results().len(), 5);
        for (i, r) in eng.results().iter().enumerate() {
            assert_eq!(r.episode, i + 1, "results in episode order");
            assert_eq!(r.expert, (i + 1) % 2 == 0);
            assert_eq!(r.steps, 10, "100 s cycle / 10 s interval");
            assert_eq!(eng.buffer(i).len(), 10);
            assert!(r.mean_reward.is_finite() && r.bootstrap.is_finite());
            for tr in &eng.buffer(i).transitions {
                assert_eq!(tr.state.len(), STATE_DIM);
                assert_eq!(tr.action_idx.len(), ACT_DIM);
                assert!(tr.value.is_finite());
            }
            if !r.expert {
                // sampled actions must carry their (negative) log-probs
                assert!(eng.buffer(i).transitions.iter().all(|t| t.logp < 0.0));
            }
        }
    }

    #[test]
    fn more_lanes_than_episodes_is_fine() {
        let params = small_params(2);
        let mut eng = RolloutEngine::new(8, 2);
        let w = wave(2, 7, 0);
        eng.collect_wave(&params, &w, &mut factory);
        assert_eq!(eng.results().len(), 2);
        assert!(eng.results().iter().all(|r| r.steps == 10));
    }

    fn result_bits(eng: &RolloutEngine) -> Vec<u64> {
        eng.results()
            .iter()
            .flat_map(|r| {
                [
                    r.episode as u64,
                    r.expert as u64,
                    r.mean_reward.to_bits(),
                    r.bootstrap.to_bits(),
                    r.steps as u64,
                ]
            })
            .collect()
    }

    #[test]
    fn persistent_pool_survives_waves_and_resizing() {
        let params = small_params(9);
        let w = wave(4, 60, 2);
        let mut eng = RolloutEngine::new(4, 3);
        eng.collect_wave(&params, &w, &mut factory);
        let want = result_bits(&eng);
        // same engine, next wave: the pool is reused, results identical
        eng.collect_wave(&params, &w, &mut factory);
        assert_eq!(want, result_bits(&eng));
        // a resized thread count rebuilds the pool without changing results
        eng.threads = 2;
        eng.collect_wave(&params, &w, &mut factory);
        assert_eq!(want, result_bits(&eng));
        // and the single-thread (poolless) path agrees bitwise
        let mut seq = RolloutEngine::new(4, 1);
        seq.collect_wave(&params, &w, &mut factory);
        assert_eq!(want, result_bits(&seq));
    }

    #[test]
    fn exhaustive_expert_solver_changes_nothing() {
        let params = small_params(10);
        let w = wave(4, 77, 2); // episodes 2 and 4 are expert-driven
        let mut fast = RolloutEngine::new(2, 2);
        fast.collect_wave(&params, &w, &mut factory);
        let mut slow = RolloutEngine::new(2, 2);
        slow.expert_exhaustive = true;
        slow.collect_wave(&params, &w, &mut factory);
        assert_eq!(result_bits(&fast), result_bits(&slow));
        for i in 0..w.len() {
            let (a, b) = (fast.buffer(i), slow.buffer(i));
            assert_eq!(a.len(), b.len());
            for (ta, tb) in a.transitions.iter().zip(&b.transitions) {
                assert_eq!(ta.action_idx, tb.action_idx, "episode {i}");
                assert_eq!(ta.reward.to_bits(), tb.reward.to_bits());
                assert_eq!(ta.logp.to_bits(), tb.logp.to_bits());
            }
        }
    }

    #[test]
    fn engine_reuse_across_waves_is_allocation_free() {
        let params = small_params(3);
        let mut eng = RolloutEngine::new(2, 2);
        let w = wave(4, 11, 2);
        eng.collect_wave(&params, &w, &mut factory);
        let warm = eng.grow_events();
        for round in 0..3 {
            let w = wave(4, 100 + round, 2);
            eng.collect_wave(&params, &w, &mut factory);
            assert_eq!(eng.grow_events(), warm, "wave {round} must reuse warm buffers");
        }
    }
}
