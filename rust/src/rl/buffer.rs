//! Rollout buffer (the replay memory D of Algorithm 2): accumulates
//! per-decision records, finalizes with GAE, and assembles the fixed-shape
//! minibatches the AOT train step consumes.

use crate::nn::spec::*;
use crate::rl::gae::gae;
use crate::util::prng::Pcg32;

/// One decision's worth of training data.
#[derive(Clone, Debug, Default)]
pub struct Transition {
    pub state: Vec<f32>,       // STATE_DIM
    pub action_idx: Vec<usize>, // ACT_DIM
    pub logp: f32,
    pub value: f32,
    pub reward: f64,
    pub head_mask: Vec<bool>, // LOGITS_DIM
    pub task_mask: Vec<bool>, // MAX_TASKS
}

/// A finalized, fixed-shape minibatch (flat row-major buffers, ready to be
/// staged as PJRT inputs of the policy_train program).
#[derive(Clone, Debug)]
pub struct Minibatch {
    pub states: Vec<f32>,    // TRAIN_BATCH × STATE_DIM
    pub actions: Vec<f32>,   // TRAIN_BATCH × ACT_DIM (f32 indices)
    pub old_logp: Vec<f32>,  // TRAIN_BATCH
    pub adv: Vec<f32>,       // TRAIN_BATCH
    pub ret: Vec<f32>,       // TRAIN_BATCH
    pub head_mask: Vec<f32>, // TRAIN_BATCH × LOGITS_DIM
    pub task_mask: Vec<f32>, // TRAIN_BATCH × MAX_TASKS
}

impl Minibatch {
    /// Synthetic minibatch for tests, benches and train-step diagnostics:
    /// random states, uniformly sampled actions, full head masks, and the
    /// alternating task-mask shape real specs produce (tail tasks masked on
    /// odd rows). `old_logp` is the near-uniform-policy log-prob per row,
    /// keeping importance ratios sane out of the box; callers that need a
    /// specific rollout policy overwrite it.
    pub fn synthetic(rng: &mut Pcg32, rows: usize) -> Minibatch {
        let mut mb = Minibatch {
            states: Vec::new(),
            actions: Vec::new(),
            old_logp: Vec::new(),
            adv: Vec::new(),
            ret: Vec::new(),
            head_mask: Vec::new(),
            task_mask: Vec::new(),
        };
        let uni: f32 =
            (MAX_VARIANTS as f32).ln() + (F_MAX as f32).ln() + (N_BATCH as f32).ln();
        for r in 0..rows {
            for _ in 0..STATE_DIM {
                mb.states.push((rng.normal() * 0.4) as f32);
            }
            for _ in 0..MAX_TASKS {
                mb.actions.push(rng.below(MAX_VARIANTS as u32) as f32);
                mb.actions.push(rng.below(F_MAX as u32) as f32);
                mb.actions.push(rng.below(N_BATCH as u32) as f32);
            }
            mb.adv.push(rng.normal() as f32);
            mb.ret.push(rng.normal() as f32);
            for _ in 0..LOGITS_DIM {
                mb.head_mask.push(1.0);
            }
            let mut active_tasks = 0usize;
            for t in 0..MAX_TASKS {
                let active = t < 4 || r % 2 == 0;
                active_tasks += active as usize;
                mb.task_mask.push(if active { 1.0 } else { 0.0 });
            }
            mb.old_logp.push(-(active_tasks as f32) * uni);
        }
        mb
    }

    /// Number of rows, derived from the state matrix. The AOT train step is
    /// compiled for exactly TRAIN_BATCH rows, but the native fused step
    /// handles partial final minibatches — consumers must use this instead
    /// of assuming TRAIN_BATCH.
    pub fn rows(&self) -> usize {
        debug_assert_eq!(self.states.len() % STATE_DIM, 0);
        let rows = self.states.len() / STATE_DIM;
        debug_assert_eq!(self.actions.len(), rows * ACT_DIM);
        debug_assert_eq!(self.old_logp.len(), rows);
        debug_assert_eq!(self.adv.len(), rows);
        debug_assert_eq!(self.ret.len(), rows);
        debug_assert_eq!(self.head_mask.len(), rows * LOGITS_DIM);
        debug_assert_eq!(self.task_mask.len(), rows * MAX_TASKS);
        rows
    }
}

#[derive(Default)]
pub struct RolloutBuffer {
    pub transitions: Vec<Transition>,
    /// retired Transition shells kept for reuse (`recycle` / `push_slot`):
    /// their inner Vecs keep their capacity, so a warm rollout lane fills
    /// episodes without allocating (DESIGN.md §9)
    spare: Vec<Transition>,
    /// number of Transition shells that had to be freshly allocated — flat
    /// once the lane has seen its steady-state episode length
    grow_events: u64,
}

impl RolloutBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: Transition) {
        debug_assert_eq!(t.state.len(), STATE_DIM);
        debug_assert_eq!(t.action_idx.len(), ACT_DIM);
        self.transitions.push(t);
    }

    /// Append a transition slot reusing a retired shell when one exists
    /// (the caller overwrites every field; the inner Vec capacities are the
    /// point of the reuse). New-shell allocations bump `grow_events`.
    pub fn push_slot(&mut self) -> &mut Transition {
        let t = self.spare.pop().unwrap_or_else(|| {
            self.grow_events += 1;
            Transition::default()
        });
        self.transitions.push(t);
        self.transitions.last_mut().expect("just pushed")
    }

    /// Empty the buffer, retiring the transition shells into the spare pool
    /// instead of dropping their allocations.
    pub fn recycle(&mut self) {
        self.spare.append(&mut self.transitions);
    }

    /// How many transition shells this buffer had to allocate (see
    /// [`RolloutBuffer::push_slot`]); the rollout engine's alloc-free proof
    /// hook sums this over its lanes.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }

    pub fn len(&self) -> usize {
        self.transitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.transitions.is_empty()
    }

    pub fn clear(&mut self) {
        self.transitions.clear();
    }

    /// Compute GAE over the stored (ordered) trajectory.
    pub fn advantages(&self, last_value: f64, gamma: f64, lam: f64) -> (Vec<f64>, Vec<f64>) {
        let rewards: Vec<f64> = self.transitions.iter().map(|t| t.reward).collect();
        let values: Vec<f64> = self.transitions.iter().map(|t| t.value as f64).collect();
        gae(&rewards, &values, last_value, gamma, lam)
    }

    /// Assemble `n_batches` minibatches of TRAIN_BATCH rows each, sampling
    /// uniformly with replacement (keeps every update the same size, as the
    /// paper's complexity analysis assumes).
    pub fn minibatches(
        &self,
        adv: &[f64],
        ret: &[f64],
        n_batches: usize,
        rng: &mut Pcg32,
    ) -> Vec<Minibatch> {
        assert!(!self.is_empty(), "minibatches on empty buffer");
        assert_eq!(adv.len(), self.len());
        let mut out = Vec::with_capacity(n_batches);
        for _ in 0..n_batches {
            let mut mb = Minibatch {
                states: Vec::with_capacity(TRAIN_BATCH * STATE_DIM),
                actions: Vec::with_capacity(TRAIN_BATCH * ACT_DIM),
                old_logp: Vec::with_capacity(TRAIN_BATCH),
                adv: Vec::with_capacity(TRAIN_BATCH),
                ret: Vec::with_capacity(TRAIN_BATCH),
                head_mask: Vec::with_capacity(TRAIN_BATCH * LOGITS_DIM),
                task_mask: Vec::with_capacity(TRAIN_BATCH * MAX_TASKS),
            };
            for _ in 0..TRAIN_BATCH {
                let i = rng.below(self.len() as u32) as usize;
                let t = &self.transitions[i];
                mb.states.extend_from_slice(&t.state);
                mb.actions.extend(t.action_idx.iter().map(|&a| a as f32));
                mb.old_logp.push(t.logp);
                mb.adv.push(adv[i] as f32);
                mb.ret.push(ret[i] as f32);
                mb.head_mask.extend(t.head_mask.iter().map(|&m| m as u8 as f32));
                mb.task_mask.extend(t.task_mask.iter().map(|&m| m as u8 as f32));
            }
            out.push(mb);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_transition(seed: u64) -> Transition {
        let mut rng = Pcg32::new(seed);
        Transition {
            state: (0..STATE_DIM).map(|_| rng.uniform() as f32).collect(),
            action_idx: (0..ACT_DIM).map(|_| rng.below(4) as usize).collect(),
            logp: -3.0,
            value: rng.uniform() as f32,
            reward: rng.uniform(),
            head_mask: vec![true; LOGITS_DIM],
            task_mask: vec![true; MAX_TASKS],
        }
    }

    #[test]
    fn push_and_advantages() {
        let mut b = RolloutBuffer::new();
        for i in 0..10 {
            b.push(fake_transition(i));
        }
        assert_eq!(b.len(), 10);
        let (adv, ret) = b.advantages(0.0, 0.99, 0.95);
        assert_eq!(adv.len(), 10);
        assert_eq!(ret.len(), 10);
        assert!(adv.iter().all(|a| a.is_finite()));
    }

    #[test]
    fn minibatch_shapes() {
        let mut b = RolloutBuffer::new();
        for i in 0..5 {
            b.push(fake_transition(i));
        }
        let (adv, ret) = b.advantages(0.0, 0.99, 0.95);
        let mut rng = Pcg32::new(0);
        let mbs = b.minibatches(&adv, &ret, 3, &mut rng);
        assert_eq!(mbs.len(), 3);
        for mb in &mbs {
            assert_eq!(mb.states.len(), TRAIN_BATCH * STATE_DIM);
            assert_eq!(mb.actions.len(), TRAIN_BATCH * ACT_DIM);
            assert_eq!(mb.old_logp.len(), TRAIN_BATCH);
            assert_eq!(mb.head_mask.len(), TRAIN_BATCH * LOGITS_DIM);
            assert_eq!(mb.task_mask.len(), TRAIN_BATCH * MAX_TASKS);
            assert!(mb.actions.iter().all(|a| a.fract() == 0.0));
            assert!(mb.head_mask.iter().all(|m| *m == 0.0 || *m == 1.0));
        }
    }

    #[test]
    fn minibatch_rows_derived_from_states() {
        let mut b = RolloutBuffer::new();
        for i in 0..4 {
            b.push(fake_transition(i));
        }
        let (adv, ret) = b.advantages(0.0, 0.99, 0.95);
        let mut rng = Pcg32::new(1);
        let mb = &b.minibatches(&adv, &ret, 1, &mut rng)[0];
        assert_eq!(mb.rows(), TRAIN_BATCH);
        // partial minibatch: truncate to 5 rows and re-derive
        let mut partial = mb.clone();
        partial.states.truncate(5 * STATE_DIM);
        partial.actions.truncate(5 * ACT_DIM);
        partial.old_logp.truncate(5);
        partial.adv.truncate(5);
        partial.ret.truncate(5);
        partial.head_mask.truncate(5 * LOGITS_DIM);
        partial.task_mask.truncate(5 * MAX_TASKS);
        assert_eq!(partial.rows(), 5);
    }

    #[test]
    fn clear_resets() {
        let mut b = RolloutBuffer::new();
        b.push(fake_transition(0));
        b.clear();
        assert!(b.is_empty());
    }

    #[test]
    fn recycle_reuses_transition_shells() {
        let mut b = RolloutBuffer::new();
        for _ in 0..5 {
            let t = b.push_slot();
            t.state.clear();
            t.state.resize(STATE_DIM, 0.5);
            t.action_idx.clear();
            t.action_idx.resize(ACT_DIM, 0);
        }
        assert_eq!(b.len(), 5);
        assert_eq!(b.grow_events(), 5, "cold buffer allocates every shell");
        b.recycle();
        assert!(b.is_empty());
        for _ in 0..5 {
            let _ = b.push_slot();
        }
        assert_eq!(b.grow_events(), 5, "warm refill must reuse retired shells");
        // one past the warm depth allocates exactly one more
        let _ = b.push_slot();
        assert_eq!(b.grow_events(), 6);
    }

    #[test]
    #[should_panic]
    fn minibatches_on_empty_buffer_panics() {
        let b = RolloutBuffer::new();
        let mut rng = Pcg32::new(0);
        b.minibatches(&[], &[], 1, &mut rng);
    }
}
