//! Rollout buffer (the replay memory D of Algorithm 2): accumulates
//! per-decision records, finalizes with GAE, and assembles the fixed-shape
//! minibatches the AOT train step consumes.

use crate::nn::spec::*;
use crate::rl::gae::gae;
use crate::util::prng::Pcg32;

/// One decision's worth of training data.
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,       // STATE_DIM
    pub action_idx: Vec<usize>, // ACT_DIM
    pub logp: f32,
    pub value: f32,
    pub reward: f64,
    pub head_mask: Vec<bool>, // LOGITS_DIM
    pub task_mask: Vec<bool>, // MAX_TASKS
}

/// A finalized, fixed-shape minibatch (flat row-major buffers, ready to be
/// staged as PJRT inputs of the policy_train program).
#[derive(Clone, Debug)]
pub struct Minibatch {
    pub states: Vec<f32>,    // TRAIN_BATCH × STATE_DIM
    pub actions: Vec<f32>,   // TRAIN_BATCH × ACT_DIM (f32 indices)
    pub old_logp: Vec<f32>,  // TRAIN_BATCH
    pub adv: Vec<f32>,       // TRAIN_BATCH
    pub ret: Vec<f32>,       // TRAIN_BATCH
    pub head_mask: Vec<f32>, // TRAIN_BATCH × LOGITS_DIM
    pub task_mask: Vec<f32>, // TRAIN_BATCH × MAX_TASKS
}

#[derive(Default)]
pub struct RolloutBuffer {
    pub transitions: Vec<Transition>,
}

impl RolloutBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: Transition) {
        debug_assert_eq!(t.state.len(), STATE_DIM);
        debug_assert_eq!(t.action_idx.len(), ACT_DIM);
        self.transitions.push(t);
    }

    pub fn len(&self) -> usize {
        self.transitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.transitions.is_empty()
    }

    pub fn clear(&mut self) {
        self.transitions.clear();
    }

    /// Compute GAE over the stored (ordered) trajectory.
    pub fn advantages(&self, last_value: f64, gamma: f64, lam: f64) -> (Vec<f64>, Vec<f64>) {
        let rewards: Vec<f64> = self.transitions.iter().map(|t| t.reward).collect();
        let values: Vec<f64> = self.transitions.iter().map(|t| t.value as f64).collect();
        gae(&rewards, &values, last_value, gamma, lam)
    }

    /// Assemble `n_batches` minibatches of TRAIN_BATCH rows each, sampling
    /// uniformly with replacement (keeps every update the same size, as the
    /// paper's complexity analysis assumes).
    pub fn minibatches(
        &self,
        adv: &[f64],
        ret: &[f64],
        n_batches: usize,
        rng: &mut Pcg32,
    ) -> Vec<Minibatch> {
        assert!(!self.is_empty(), "minibatches on empty buffer");
        assert_eq!(adv.len(), self.len());
        let mut out = Vec::with_capacity(n_batches);
        for _ in 0..n_batches {
            let mut mb = Minibatch {
                states: Vec::with_capacity(TRAIN_BATCH * STATE_DIM),
                actions: Vec::with_capacity(TRAIN_BATCH * ACT_DIM),
                old_logp: Vec::with_capacity(TRAIN_BATCH),
                adv: Vec::with_capacity(TRAIN_BATCH),
                ret: Vec::with_capacity(TRAIN_BATCH),
                head_mask: Vec::with_capacity(TRAIN_BATCH * LOGITS_DIM),
                task_mask: Vec::with_capacity(TRAIN_BATCH * MAX_TASKS),
            };
            for _ in 0..TRAIN_BATCH {
                let i = rng.below(self.len() as u32) as usize;
                let t = &self.transitions[i];
                mb.states.extend_from_slice(&t.state);
                mb.actions.extend(t.action_idx.iter().map(|&a| a as f32));
                mb.old_logp.push(t.logp);
                mb.adv.push(adv[i] as f32);
                mb.ret.push(ret[i] as f32);
                mb.head_mask.extend(t.head_mask.iter().map(|&m| m as u8 as f32));
                mb.task_mask.extend(t.task_mask.iter().map(|&m| m as u8 as f32));
            }
            out.push(mb);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_transition(seed: u64) -> Transition {
        let mut rng = Pcg32::new(seed);
        Transition {
            state: (0..STATE_DIM).map(|_| rng.uniform() as f32).collect(),
            action_idx: (0..ACT_DIM).map(|_| rng.below(4) as usize).collect(),
            logp: -3.0,
            value: rng.uniform() as f32,
            reward: rng.uniform(),
            head_mask: vec![true; LOGITS_DIM],
            task_mask: vec![true; MAX_TASKS],
        }
    }

    #[test]
    fn push_and_advantages() {
        let mut b = RolloutBuffer::new();
        for i in 0..10 {
            b.push(fake_transition(i));
        }
        assert_eq!(b.len(), 10);
        let (adv, ret) = b.advantages(0.0, 0.99, 0.95);
        assert_eq!(adv.len(), 10);
        assert_eq!(ret.len(), 10);
        assert!(adv.iter().all(|a| a.is_finite()));
    }

    #[test]
    fn minibatch_shapes() {
        let mut b = RolloutBuffer::new();
        for i in 0..5 {
            b.push(fake_transition(i));
        }
        let (adv, ret) = b.advantages(0.0, 0.99, 0.95);
        let mut rng = Pcg32::new(0);
        let mbs = b.minibatches(&adv, &ret, 3, &mut rng);
        assert_eq!(mbs.len(), 3);
        for mb in &mbs {
            assert_eq!(mb.states.len(), TRAIN_BATCH * STATE_DIM);
            assert_eq!(mb.actions.len(), TRAIN_BATCH * ACT_DIM);
            assert_eq!(mb.old_logp.len(), TRAIN_BATCH);
            assert_eq!(mb.head_mask.len(), TRAIN_BATCH * LOGITS_DIM);
            assert_eq!(mb.task_mask.len(), TRAIN_BATCH * MAX_TASKS);
            assert!(mb.actions.iter().all(|a| a.fract() == 0.0));
            assert!(mb.head_mask.iter().all(|m| *m == 0.0 || *m == 1.0));
        }
    }

    #[test]
    fn clear_resets() {
        let mut b = RolloutBuffer::new();
        b.push(fake_transition(0));
        b.clear();
        assert!(b.is_empty());
    }

    #[test]
    #[should_panic]
    fn minibatches_on_empty_buffer_panics() {
        let b = RolloutBuffer::new();
        let mut rng = Pcg32::new(0);
        b.minibatches(&[], &[], 1, &mut rng);
    }
}
