//! Time-series store: named per-second series with bounded retention — the
//! part of the Prometheus stand-in the agent reads back (incoming load for
//! the predictor window, per-stage QoS/cost series for the Fig. 4 plots).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::workload::trace::LoadHistory;

/// Bounded multi-series store.
pub struct TimeSeriesStore {
    retention: usize,
    series: Mutex<BTreeMap<String, LoadHistory>>,
}

impl TimeSeriesStore {
    pub fn new(retention: usize) -> Self {
        Self { retention, series: Mutex::new(BTreeMap::new()) }
    }

    pub fn record(&self, name: &str, value: f64) {
        let mut g = self.series.lock().unwrap();
        g.entry(name.to_string())
            .or_insert_with(|| LoadHistory::new(self.retention))
            .push(value);
    }

    pub fn latest(&self, name: &str) -> Option<f64> {
        self.series.lock().unwrap().get(name).and_then(|h| h.latest())
    }

    /// Last `n` values (left-padded; see LoadHistory::window). Empty vec when
    /// the series does not exist.
    pub fn window(&self, name: &str, n: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.window_into(name, n, &mut out);
        out
    }

    /// [`TimeSeriesStore::window`] into a caller-owned buffer (cleared
    /// first) — the leader publish tick reads series every second, so the
    /// fresh-`Vec`-per-call variant is hot-loop churn. The buffer is left
    /// empty when the series does not exist.
    pub fn window_into(&self, name: &str, n: usize, out: &mut Vec<f64>) {
        out.clear();
        if let Some(h) = self.series.lock().unwrap().get(name) {
            h.window_into(n, out);
        }
    }

    /// Drop a series outright. Returns true when it existed. The leader
    /// calls this on tenant delete so per-pipeline series do not accumulate
    /// across deploy/remove churn (DESIGN.md §15).
    pub fn remove(&self, name: &str) -> bool {
        self.series.lock().unwrap().remove(name).is_some()
    }

    pub fn len(&self, name: &str) -> usize {
        self.series.lock().unwrap().get(name).map(|h| h.len()).unwrap_or(0)
    }

    pub fn names(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.for_each_name(|n| out.push(n.to_string()));
        out
    }

    /// Visit every series name without cloning the key set — the borrow
    /// variant of [`TimeSeriesStore::names`] for per-tick consumers.
    pub fn for_each_name(&self, mut f: impl FnMut(&str)) {
        for name in self.series.lock().unwrap().keys() {
            f(name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_read_back() {
        let ts = TimeSeriesStore::new(100);
        for i in 0..5 {
            ts.record("load", i as f64);
        }
        assert_eq!(ts.latest("load"), Some(4.0));
        assert_eq!(ts.window("load", 3), vec![2.0, 3.0, 4.0]);
        assert_eq!(ts.len("load"), 5);
    }

    #[test]
    fn missing_series() {
        let ts = TimeSeriesStore::new(10);
        assert_eq!(ts.latest("x"), None);
        assert!(ts.window("x", 3).is_empty());
        assert_eq!(ts.len("x"), 0);
    }

    #[test]
    fn retention_bounds_memory() {
        let ts = TimeSeriesStore::new(3);
        for i in 0..10 {
            ts.record("s", i as f64);
        }
        assert_eq!(ts.len("s"), 3);
        assert_eq!(ts.window("s", 3), vec![7.0, 8.0, 9.0]);
    }

    #[test]
    fn multiple_series_isolated() {
        let ts = TimeSeriesStore::new(10);
        ts.record("a", 1.0);
        ts.record("b", 2.0);
        assert_eq!(ts.latest("a"), Some(1.0));
        assert_eq!(ts.latest("b"), Some(2.0));
        assert_eq!(ts.names(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn remove_drops_one_series() {
        let ts = TimeSeriesStore::new(10);
        ts.record("load:a", 1.0);
        ts.record("load:b", 2.0);
        assert!(ts.remove("load:a"));
        assert!(!ts.remove("load:a"), "already gone");
        assert_eq!(ts.latest("load:a"), None);
        assert_eq!(ts.latest("load:b"), Some(2.0));
        assert_eq!(ts.names(), vec!["load:b".to_string()]);
    }

    #[test]
    fn window_into_and_for_each_name_match_allocating_variants() {
        let ts = TimeSeriesStore::new(10);
        for i in 0..4 {
            ts.record("load", i as f64);
        }
        ts.record("qos", 1.0);
        let mut buf = Vec::new();
        ts.window_into("load", 3, &mut buf);
        assert_eq!(buf, ts.window("load", 3));
        ts.window_into("missing", 3, &mut buf);
        assert!(buf.is_empty());
        let mut seen = Vec::new();
        ts.for_each_name(|n| seen.push(n.to_string()));
        assert_eq!(seen, ts.names());
    }
}
