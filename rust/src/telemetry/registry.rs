//! Metrics registry with Prometheus text-format exposition.
//!
//! Counters, gauges and histograms are keyed by `name{label="value",...}`.
//! The serve layer exposes `/metrics` in the text format Prometheus scrapes,
//! so the monitoring story matches the paper's deployment (§V-B: "integration
//! with Prometheus and Grafana is also possible").

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::Histogram;

/// Fully-qualified metric key: name + sorted label pairs.
fn key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut ls: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    ls.sort();
    let inner: Vec<String> =
        ls.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", inner.join(","))
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    help: BTreeMap<String, String>,
}

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

/// Point-in-time copy of all scalar metrics (for state building / tests).
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, f64>,
    pub gauges: BTreeMap<String, f64>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn describe(&self, name: &str, help: &str) {
        let mut g = self.inner.lock().unwrap();
        g.help.insert(name.to_string(), help.to_string());
    }

    pub fn inc(&self, name: &str, labels: &[(&str, &str)], by: f64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(key(name, labels)).or_insert(0.0) += by;
    }

    pub fn set_gauge(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(key(name, labels), value);
    }

    pub fn observe(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms
            .entry(key(name, labels))
            .or_insert_with(|| Histogram::exponential(0.001, 2.0, 18))
            .observe(value);
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        self.inner.lock().unwrap().counters.get(&key(name, labels)).copied().unwrap_or(0.0)
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(&key(name, labels)).copied()
    }

    /// Drop every metric registered under `name{labels}` (counter, gauge or
    /// histogram). Returns true when anything was removed. The leader calls
    /// this when a tenant is deleted so per-pipeline gauges do not pin label
    /// cardinality forever (DESIGN.md §15).
    pub fn remove_series(&self, name: &str, labels: &[(&str, &str)]) -> bool {
        let k = key(name, labels);
        let mut g = self.inner.lock().unwrap();
        let mut hit = g.counters.remove(&k).is_some();
        hit |= g.gauges.remove(&k).is_some();
        hit |= g.histograms.remove(&k).is_some();
        hit
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot { counters: g.counters.clone(), gauges: g.gauges.clone() }
    }

    /// Prometheus text exposition format (v0.0.4).
    pub fn expose(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        let mut seen_help: Vec<&str> = Vec::new();
        let mut help_for = |out: &mut String, full: &str, kind: &str| {
            let base = full.split('{').next().unwrap_or(full);
            if !seen_help.contains(&base) {
                if let Some(h) = g.help.get(base) {
                    out.push_str(&format!("# HELP {base} {h}\n"));
                }
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                // leak a 'static-ish copy via Box is overkill; track by String
                seen_help.push(Box::leak(base.to_string().into_boxed_str()));
            }
        };
        for (k, v) in &g.counters {
            help_for(&mut out, k, "counter");
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, v) in &g.gauges {
            help_for(&mut out, k, "gauge");
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in &g.histograms {
            help_for(&mut out, k, "histogram");
            let (base, labels) = match k.find('{') {
                Some(i) => (&k[..i], k[i + 1..k.len() - 1].to_string()),
                None => (k.as_str(), String::new()),
            };
            let mut cum = 0u64;
            for (bound, count) in h.buckets() {
                cum += count;
                let le = if bound.is_infinite() { "+Inf".to_string() } else { format!("{bound}") };
                let sep = if labels.is_empty() { String::new() } else { format!("{labels},") };
                out.push_str(&format!("{base}_bucket{{{sep}le=\"{le}\"}} {cum}\n"));
            }
            let lbl = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
            out.push_str(&format!("{base}_sum{lbl} {}\n", h.sum()));
            out.push_str(&format!("{base}_count{lbl} {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = MetricsRegistry::new();
        r.inc("requests_total", &[("stage", "0")], 1.0);
        r.inc("requests_total", &[("stage", "0")], 2.0);
        r.inc("requests_total", &[("stage", "1")], 5.0);
        assert_eq!(r.counter("requests_total", &[("stage", "0")]), 3.0);
        assert_eq!(r.counter("requests_total", &[("stage", "1")]), 5.0);
        assert_eq!(r.counter("requests_total", &[("stage", "9")]), 0.0);
    }

    #[test]
    fn label_order_is_canonical() {
        let r = MetricsRegistry::new();
        r.inc("m", &[("b", "2"), ("a", "1")], 1.0);
        assert_eq!(r.counter("m", &[("a", "1"), ("b", "2")]), 1.0);
    }

    #[test]
    fn gauges_overwrite() {
        let r = MetricsRegistry::new();
        r.set_gauge("load", &[], 10.0);
        r.set_gauge("load", &[], 20.0);
        assert_eq!(r.gauge("load", &[]), Some(20.0));
        assert_eq!(r.gauge("nope", &[]), None);
    }

    #[test]
    fn exposition_format_contains_series() {
        let r = MetricsRegistry::new();
        r.describe("qos", "pipeline QoS (Eq. 3)");
        r.set_gauge("qos", &[("algo", "opd")], 3.5);
        r.inc("decisions_total", &[], 7.0);
        r.observe("decision_seconds", &[], 0.004);
        let text = r.expose();
        assert!(text.contains("# HELP qos pipeline QoS (Eq. 3)"));
        assert!(text.contains("# TYPE qos gauge"));
        assert!(text.contains("qos{algo=\"opd\"} 3.5"));
        assert!(text.contains("decisions_total 7"));
        assert!(text.contains("decision_seconds_bucket"));
        assert!(text.contains("decision_seconds_count 1"));
        assert!(text.contains("le=\"+Inf\""));
    }

    #[test]
    fn histogram_cumulative_buckets() {
        let r = MetricsRegistry::new();
        for v in [0.002, 0.002, 10.0] {
            r.observe("lat", &[], v);
        }
        let text = r.expose();
        assert!(text.contains("lat_count 3"));
        // +Inf bucket must equal total count
        let inf_line = text.lines().find(|l| l.contains("le=\"+Inf\"")).unwrap();
        assert!(inf_line.ends_with(" 3"), "{inf_line}");
    }

    #[test]
    fn remove_series_evicts_all_kinds() {
        let r = MetricsRegistry::new();
        r.set_gauge("qos", &[("pipeline", "a")], 3.5);
        r.set_gauge("qos", &[("pipeline", "b")], 4.0);
        r.inc("hits", &[("pipeline", "a")], 2.0);
        r.observe("lat", &[("pipeline", "a")], 0.01);
        assert!(r.remove_series("qos", &[("pipeline", "a")]));
        assert!(r.remove_series("hits", &[("pipeline", "a")]));
        assert!(r.remove_series("lat", &[("pipeline", "a")]));
        assert!(!r.remove_series("qos", &[("pipeline", "a")]), "already gone");
        assert_eq!(r.gauge("qos", &[("pipeline", "a")]), None);
        assert_eq!(r.gauge("qos", &[("pipeline", "b")]), Some(4.0), "others untouched");
        let text = r.expose();
        assert!(!text.contains("pipeline=\"a\""), "{text}");
    }

    #[test]
    fn snapshot_is_point_in_time() {
        let r = MetricsRegistry::new();
        r.inc("c", &[], 1.0);
        let snap = r.snapshot();
        r.inc("c", &[], 1.0);
        assert_eq!(snap.counters.get("c"), Some(&1.0));
        assert_eq!(r.counter("c", &[]), 2.0);
    }
}
