//! Monitoring substrate — the Prometheus stand-in (paper §III-A
//! "Monitoring"): a metrics registry (counters / gauges / histograms) with
//! Prometheus text exposition, and a time-series store that retains the
//! per-second samples the RL agent's state builder and the LSTM predictor
//! read back.

pub mod registry;
pub mod timeseries;

pub use registry::{MetricsRegistry, MetricsSnapshot};
pub use timeseries::TimeSeriesStore;
