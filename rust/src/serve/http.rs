//! Minimal HTTP/1.1 server substrate (std::net + a fixed thread pool; no
//! tokio offline). Enough surface for the leader process: GET/POST routing,
//! request bodies, content types, graceful shutdown.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub body: String,
}

/// Response under construction.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: String,
}

impl Response {
    pub fn ok(body: impl Into<String>) -> Self {
        Self { status: 200, content_type: "text/plain; charset=utf-8".into(), body: body.into() }
    }

    pub fn json(body: impl Into<String>) -> Self {
        Self { status: 200, content_type: "application/json".into(), body: body.into() }
    }

    pub fn not_found() -> Self {
        Self { status: 404, content_type: "text/plain".into(), body: "not found\n".into() }
    }

    pub fn bad_request(msg: impl Into<String>) -> Self {
        Self { status: 400, content_type: "text/plain".into(), body: msg.into() }
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            500 => "Internal Server Error",
            _ => "Unknown",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// Route table: (METHOD, path) → handler.
#[derive(Default, Clone)]
pub struct Router {
    routes: HashMap<(String, String), Handler>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.routes.insert(("GET".into(), path.into()), Arc::new(f));
        self
    }

    pub fn post<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.routes.insert(("POST".into(), path.into()), Arc::new(f));
        self
    }

    pub fn dispatch(&self, req: &Request) -> Response {
        match self.routes.get(&(req.method.clone(), req.path.clone())) {
            Some(h) => h(req),
            None => Response::not_found(),
        }
    }
}

fn parse_request(stream: &mut TcpStream) -> std::io::Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    // headers
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request {
        method,
        path,
        query,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

/// Running server handle.
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on `addr` (e.g. "127.0.0.1:0" for an ephemeral port)
    /// with `workers` handler threads.
    pub fn start(addr: &str, router: Router, workers: usize) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let router = Arc::new(router);
        // worker pool
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let router = router.clone();
            std::thread::spawn(move || loop {
                let stream = { rx.lock().unwrap().recv() };
                match stream {
                    Ok(mut s) => {
                        let resp = match parse_request(&mut s) {
                            Ok(req) => router.dispatch(&req),
                            Err(e) => Response::bad_request(format!("parse error: {e}\n")),
                        };
                        let _ = resp.write_to(&mut s);
                    }
                    Err(_) => break, // channel closed → shut down
                }
            });
        }
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nonblocking(false);
                        if tx.send(s).is_err() {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            drop(tx);
        });
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// Tiny client helper (tests, CLI health checks).
pub fn http_get(addr: &std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n");
    s.write_all(req.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|x| x.parse().ok())
        .unwrap_or(0);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

pub fn http_post(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 =
        buf.split_whitespace().nth(1).and_then(|x| x.parse().ok()).unwrap_or(0);
    let resp_body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, resp_body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_post_roundtrip() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        router.post("/echo", |req| Response::ok(req.body.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let addr = server.addr;

        let (code, body) = http_get(&addr, "/ping").unwrap();
        assert_eq!((code, body.as_str()), (200, "pong"));

        let (code, body) = http_post(&addr, "/echo", "hello world").unwrap();
        assert_eq!((code, body.as_str()), (200, "hello world"));

        let (code, _) = http_get(&addr, "/missing").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn query_strings_are_split() {
        let mut router = Router::new();
        router.get("/q", |req| Response::ok(req.query.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let (code, body) = http_get(&server.addr, "/q?a=1&b=2").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "a=1&b=2");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let mut router = Router::new();
        router.get("/x", |_| Response::ok("y"));
        let server = HttpServer::start("127.0.0.1:0", router, 4).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(move || http_get(&addr, "/x").unwrap().0))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
        server.shutdown();
    }
}
