//! Minimal HTTP/1.1 server substrate (std::net + a fixed thread pool; no
//! tokio offline). Enough surface for the leader process: GET/POST/PUT/DELETE
//! routing with path parameters (`/v1/pipelines/{name}`), request bodies with
//! a hard size cap, content types, graceful shutdown that joins every thread.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Largest request body the server accepts; larger declared lengths are
/// rejected with 413 instead of being silently truncated.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub body: String,
    /// path parameters captured by `{name}` route segments
    pub params: HashMap<String, String>,
}

impl Request {
    /// Path parameter by name ("" when the route declared none).
    pub fn param(&self, name: &str) -> &str {
        self.params.get(name).map(String::as_str).unwrap_or("")
    }
}

/// Response under construction.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: String,
}

impl Response {
    pub fn ok(body: impl Into<String>) -> Self {
        Self { status: 200, content_type: "text/plain; charset=utf-8".into(), body: body.into() }
    }

    pub fn json(body: impl Into<String>) -> Self {
        Self::json_with_status(200, body)
    }

    pub fn json_with_status(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "application/json".into(), body: body.into() }
    }

    pub fn with_status(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "text/plain".into(), body: body.into() }
    }

    pub fn not_found() -> Self {
        Self::with_status(404, "not found\n")
    }

    pub fn bad_request(msg: impl Into<String>) -> Self {
        Self::with_status(400, msg)
    }

    pub fn method_not_allowed() -> Self {
        Self::with_status(405, "method not allowed\n")
    }

    pub fn payload_too_large(declared: usize) -> Self {
        Self::with_status(
            413,
            format!("request body of {declared} bytes exceeds the {MAX_BODY_BYTES}-byte cap\n"),
        )
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// One path segment of a pattern route.
#[derive(Clone, Debug)]
enum Seg {
    Lit(String),
    Param(String),
}

#[derive(Clone)]
struct PatternRoute {
    method: String,
    segs: Vec<Seg>,
    handler: Handler,
}

/// Route table. Exact routes live in a method → path map looked up with
/// borrowed keys (no per-request allocation); routes containing `{param}`
/// segments are matched against the split path.
#[derive(Default, Clone)]
pub struct Router {
    exact: HashMap<String, HashMap<String, Handler>>,
    patterns: Vec<PatternRoute>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn route<F>(&mut self, method: &str, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let handler: Handler = Arc::new(f);
        if path.contains('{') {
            let segs = path
                .trim_start_matches('/')
                .split('/')
                .map(|s| match s.strip_prefix('{').and_then(|s| s.strip_suffix('}')) {
                    Some(p) => Seg::Param(p.to_string()),
                    None => Seg::Lit(s.to_string()),
                })
                .collect();
            self.patterns.push(PatternRoute { method: method.to_string(), segs, handler });
        } else {
            self.exact
                .entry(method.to_string())
                .or_default()
                .insert(path.to_string(), handler);
        }
        self
    }

    pub fn get<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("GET", path, f)
    }

    pub fn post<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("POST", path, f)
    }

    pub fn put<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("PUT", path, f)
    }

    pub fn delete<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("DELETE", path, f)
    }

    fn match_pattern(segs: &[Seg], path: &str) -> Option<HashMap<String, String>> {
        let parts: Vec<&str> = path.trim_start_matches('/').split('/').collect();
        if parts.len() != segs.len() {
            return None;
        }
        let mut params = HashMap::new();
        for (seg, part) in segs.iter().zip(&parts) {
            match seg {
                Seg::Lit(l) => {
                    if l != part {
                        return None;
                    }
                }
                Seg::Param(p) => {
                    if part.is_empty() {
                        return None;
                    }
                    params.insert(p.clone(), (*part).to_string());
                }
            }
        }
        Some(params)
    }

    pub fn dispatch(&self, req: &Request) -> Response {
        if let Some(h) =
            self.exact.get(req.method.as_str()).and_then(|m| m.get(req.path.as_str()))
        {
            return h(req);
        }
        for r in &self.patterns {
            if r.method == req.method {
                if let Some(params) = Self::match_pattern(&r.segs, &req.path) {
                    let mut with = req.clone();
                    with.params = params;
                    return (r.handler)(&with);
                }
            }
        }
        // the path exists under another method → 405, not 404
        let other_method = self
            .exact
            .iter()
            .any(|(m, routes)| *m != req.method && routes.contains_key(req.path.as_str()))
            || self.patterns.iter().any(|r| {
                r.method != req.method && Self::match_pattern(&r.segs, &req.path).is_some()
            });
        if other_method {
            return Response::method_not_allowed();
        }
        Response::not_found()
    }
}

enum ParseError {
    Io(std::io::Error),
    /// declared Content-Length above `MAX_BODY_BYTES`
    TooLarge(usize),
}

impl From<std::io::Error> for ParseError {
    fn from(e: std::io::Error) -> Self {
        ParseError::Io(e)
    }
}

fn parse_request(stream: &mut TcpStream) -> Result<Request, ParseError> {
    let mut reader = BufReader::new(stream.try_clone().map_err(ParseError::Io)?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    // headers
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ParseError::TooLarge(content_length));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Request {
        method,
        path,
        query,
        body: String::from_utf8_lossy(&body).into_owned(),
        params: HashMap::new(),
    })
}

/// Running server handle.
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on `addr` (e.g. "127.0.0.1:0" for an ephemeral port)
    /// with `workers` handler threads.
    pub fn start(addr: &str, router: Router, workers: usize) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let router = Arc::new(router);
        // worker pool
        let mut worker_threads = Vec::with_capacity(workers.max(1));
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let router = router.clone();
            worker_threads.push(std::thread::spawn(move || loop {
                let stream = { rx.lock().unwrap().recv() };
                match stream {
                    Ok(mut s) => {
                        let resp = match parse_request(&mut s) {
                            Ok(req) => router.dispatch(&req),
                            Err(ParseError::TooLarge(n)) => Response::payload_too_large(n),
                            Err(ParseError::Io(e)) => {
                                Response::bad_request(format!("parse error: {e}\n"))
                            }
                        };
                        let _ = resp.write_to(&mut s);
                    }
                    Err(_) => break, // channel closed → shut down
                }
            }));
        }
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nonblocking(false);
                        if tx.send(s).is_err() {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            drop(tx);
        });
        Ok(HttpServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            workers: worker_threads,
        })
    }

    /// Stop accepting, then join the accept thread *and* every worker (the
    /// accept thread dropping the channel sender is what unblocks workers).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// Tiny client helper (tests, CLI health checks and the `opd apply` client).
pub fn http_request(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> std::io::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 =
        buf.split_whitespace().nth(1).and_then(|x| x.parse().ok()).unwrap_or(0);
    let resp_body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, resp_body))
}

pub fn http_get(addr: &std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    http_request(addr, "GET", path, None)
}

pub fn http_post(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    http_request(addr, "POST", path, Some(body))
}

pub fn http_put(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    http_request(addr, "PUT", path, Some(body))
}

pub fn http_delete(addr: &std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    http_request(addr, "DELETE", path, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_post_roundtrip() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        router.post("/echo", |req| Response::ok(req.body.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let addr = server.addr;

        let (code, body) = http_get(&addr, "/ping").unwrap();
        assert_eq!((code, body.as_str()), (200, "pong"));

        let (code, body) = http_post(&addr, "/echo", "hello world").unwrap();
        assert_eq!((code, body.as_str()), (200, "hello world"));

        let (code, _) = http_get(&addr, "/missing").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn query_strings_are_split() {
        let mut router = Router::new();
        router.get("/q", |req| Response::ok(req.query.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let (code, body) = http_get(&server.addr, "/q?a=1&b=2").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "a=1&b=2");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let mut router = Router::new();
        router.get("/x", |_| Response::ok("y"));
        let server = HttpServer::start("127.0.0.1:0", router, 4).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(move || http_get(&addr, "/x").unwrap().0))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
        server.shutdown();
    }

    #[test]
    fn path_params_are_captured() {
        let mut router = Router::new();
        router.get("/v1/pipelines/{name}", |req| Response::ok(req.param("name").to_string()));
        router.post("/v1/pipelines/{name}/agent", |req| {
            Response::ok(format!("{}:{}", req.param("name"), req.body))
        });
        router.get("/v1/pipelines", |_| Response::ok("list"));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let addr = server.addr;

        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!((code, body.as_str()), (200, "vid"));
        let (code, body) = http_post(&addr, "/v1/pipelines/iot/agent", "ipa").unwrap();
        assert_eq!((code, body.as_str()), (200, "iot:ipa"));
        // exact route still wins over the pattern space
        let (code, body) = http_get(&addr, "/v1/pipelines").unwrap();
        assert_eq!((code, body.as_str()), (200, "list"));
        // unmatched depth → 404
        let (code, _) = http_get(&addr, "/v1/pipelines/a/b/c").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn wrong_method_is_405_not_404() {
        let mut router = Router::new();
        router.get("/only-get", |_| Response::ok("x"));
        router.put("/v1/pipelines/{name}", |_| Response::ok("put"));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let addr = server.addr;

        let (code, _) = http_post(&addr, "/only-get", "").unwrap();
        assert_eq!(code, 405);
        let (code, _) = http_get(&addr, "/v1/pipelines/x").unwrap();
        assert_eq!(code, 405);
        let (code, _) = http_get(&addr, "/never-registered").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn oversize_body_is_rejected_with_413() {
        let mut router = Router::new();
        router.post("/sink", |req| Response::ok(format!("{}", req.body.len())));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        // declare a body over the cap without sending it: the server must
        // answer 413 instead of truncating at 1 MiB and dispatching
        let mut s = TcpStream::connect(server.addr).unwrap();
        let head = format!(
            "POST /sink HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        s.write_all(head.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let status: u16 =
            buf.split_whitespace().nth(1).and_then(|x| x.parse().ok()).unwrap_or(0);
        assert_eq!(status, 413, "{buf}");
        // a body at the cap still works
        let body = "x".repeat(1024);
        let (code, got) = http_post(&server.addr, "/sink", &body).unwrap();
        assert_eq!((code, got.as_str()), (200, "1024"));
        server.shutdown();
    }

    #[test]
    fn put_and_delete_roundtrip() {
        let mut router = Router::new();
        router.put("/thing/{id}", |req| {
            Response::json_with_status(201, format!("{{\"id\":\"{}\"}}", req.param("id")))
        });
        router.delete("/thing/{id}", |req| Response::ok(req.param("id").to_string()));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let (code, body) = http_put(&server.addr, "/thing/42", "{}").unwrap();
        assert_eq!((code, body.as_str()), (201, "{\"id\":\"42\"}"));
        let (code, body) = http_delete(&server.addr, "/thing/42").unwrap();
        assert_eq!((code, body.as_str()), (200, "42"));
        server.shutdown();
    }
}
