//! Minimal HTTP/1.1 server substrate (std::net only; no tokio offline).
//! Enough surface for the leader process: GET/POST/PUT/DELETE routing with
//! path parameters (`/v1/pipelines/{name}`), request bodies with a hard size
//! cap, content types, graceful shutdown that joins every thread.
//!
//! Cluster-scale shape (DESIGN.md §12): a blocking accept thread deals
//! connections round-robin onto a fixed worker pool; each worker runs a
//! readiness loop over its set of **non-blocking keep-alive connections**,
//! with a per-connection state machine for incremental header+body reads and
//! partial writes. A worker with zero connections blocks on its channel (an
//! idle leader burns no CPU — the old accept loop's 5 ms `WouldBlock`
//! sleep-poll is gone, and shutdown wakes the accept thread with a loopback
//! connection instead of being polled for).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Largest request body the server accepts; larger declared lengths are
/// rejected with 413 instead of being silently truncated.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Largest header block (request line + headers) before the connection is
/// rejected with 400 — bounds buffering for clients that never finish.
const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Keep-alive connections idle longer than this are closed.
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// After shutdown starts, how long a worker keeps serving connections that
/// still have a request or response in flight.
const DRAIN_GRACE: Duration = Duration::from_millis(500);

/// Per-worker connection cap; excess connections get 503 + close.
const MAX_CONNS_PER_WORKER: usize = 512;

/// Parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: String,
    pub body: String,
    /// path parameters captured by `{name}` route segments
    pub params: HashMap<String, String>,
}

impl Request {
    fn empty() -> Request {
        Request {
            method: String::new(),
            path: String::new(),
            query: String::new(),
            body: String::new(),
            params: HashMap::new(),
        }
    }

    /// Path parameter by name ("" when the route declared none).
    pub fn param(&self, name: &str) -> &str {
        self.params.get(name).map(String::as_str).unwrap_or("")
    }
}

/// Response under construction.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: String,
}

impl Response {
    pub fn ok(body: impl Into<String>) -> Self {
        Self { status: 200, content_type: "text/plain; charset=utf-8".into(), body: body.into() }
    }

    pub fn json(body: impl Into<String>) -> Self {
        Self::json_with_status(200, body)
    }

    pub fn json_with_status(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "application/json".into(), body: body.into() }
    }

    pub fn with_status(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "text/plain".into(), body: body.into() }
    }

    pub fn not_found() -> Self {
        Self::with_status(404, "not found\n")
    }

    pub fn bad_request(msg: impl Into<String>) -> Self {
        Self::with_status(400, msg)
    }

    pub fn method_not_allowed() -> Self {
        Self::with_status(405, "method not allowed\n")
    }

    pub fn payload_too_large(declared: usize) -> Self {
        Self::with_status(
            413,
            format!("request body of {declared} bytes exceeds the {MAX_BODY_BYTES}-byte cap\n"),
        )
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        }
    }

    /// Serialize into `out` (appended). `close` selects the Connection
    /// header; responses always carry Content-Length so keep-alive clients
    /// can frame them.
    fn encode_into(&self, close: bool, out: &mut Vec<u8>) {
        let _ = write!(
            out,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
        );
        out.extend_from_slice(self.body.as_bytes());
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// One path segment of a pattern route.
#[derive(Clone, Debug)]
enum Seg {
    Lit(String),
    Param(String),
}

#[derive(Clone)]
struct PatternRoute {
    method: String,
    segs: Vec<Seg>,
    handler: Handler,
}

/// Route table. Exact routes live in a method → path map looked up with
/// borrowed keys (no per-request allocation); routes containing `{param}`
/// segments are matched against the split path.
#[derive(Default, Clone)]
pub struct Router {
    exact: HashMap<String, HashMap<String, Handler>>,
    patterns: Vec<PatternRoute>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn route<F>(&mut self, method: &str, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        let handler: Handler = Arc::new(f);
        if path.contains('{') {
            let segs = path
                .trim_start_matches('/')
                .split('/')
                .map(|s| match s.strip_prefix('{').and_then(|s| s.strip_suffix('}')) {
                    Some(p) => Seg::Param(p.to_string()),
                    None => Seg::Lit(s.to_string()),
                })
                .collect();
            self.patterns.push(PatternRoute { method: method.to_string(), segs, handler });
        } else {
            self.exact
                .entry(method.to_string())
                .or_default()
                .insert(path.to_string(), handler);
        }
        self
    }

    pub fn get<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("GET", path, f)
    }

    pub fn post<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("POST", path, f)
    }

    pub fn put<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("PUT", path, f)
    }

    pub fn delete<F>(&mut self, path: &str, f: F) -> &mut Self
    where
        F: Fn(&Request) -> Response + Send + Sync + 'static,
    {
        self.route("DELETE", path, f)
    }

    /// Match `path` against `segs`, filling `params` in place (cleared
    /// first). Returns false without touching semantics on mismatch.
    fn match_pattern_into(
        segs: &[Seg],
        path: &str,
        params: &mut HashMap<String, String>,
    ) -> bool {
        params.clear();
        let mut parts = path.trim_start_matches('/').split('/');
        for seg in segs {
            let Some(part) = parts.next() else { return false };
            match seg {
                Seg::Lit(l) => {
                    if l != part {
                        return false;
                    }
                }
                Seg::Param(p) => {
                    if part.is_empty() {
                        return false;
                    }
                    params.insert(p.clone(), part.to_string());
                }
            }
        }
        parts.next().is_none()
    }

    /// Dispatch a request, filling `req.params` in place for pattern routes
    /// (no request clone on the hot path).
    pub fn dispatch(&self, req: &mut Request) -> Response {
        if let Some(h) =
            self.exact.get(req.method.as_str()).and_then(|m| m.get(req.path.as_str()))
        {
            return h(req);
        }
        for r in &self.patterns {
            if r.method == req.method
                && Self::match_pattern_into(&r.segs, &req.path, &mut req.params)
            {
                return (r.handler)(req);
            }
        }
        // the path exists under another method → 405, not 404
        let other_method = self
            .exact
            .iter()
            .any(|(m, routes)| *m != req.method && routes.contains_key(req.path.as_str()))
            || self.patterns.iter().any(|r| {
                r.method != req.method
                    && Self::match_pattern_into(&r.segs, &req.path, &mut req.params)
            });
        if other_method {
            return Response::method_not_allowed();
        }
        Response::not_found()
    }
}

/// Per-connection state machine: accumulate input, carve complete requests
/// off the front (pipelining-capable), queue encoded responses, flush with
/// partial-write tracking. Everything non-blocking; the worker loop drives
/// `pump` on readiness.
struct Conn {
    stream: TcpStream,
    /// unparsed input bytes
    buf: Vec<u8>,
    /// resume offset for the header-terminator scan (avoids rescanning)
    scan_from: usize,
    /// encoded, not-yet-flushed response bytes
    out: Vec<u8>,
    out_pos: usize,
    /// close once `out` is flushed (Connection: close, HTTP/1.0, 413, 400)
    close_after: bool,
    /// peer shut down its write side
    eof: bool,
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        let _ = stream.set_nodelay(true);
        Ok(Conn {
            stream,
            buf: Vec::new(),
            scan_from: 0,
            out: Vec::new(),
            out_pos: 0,
            close_after: false,
            eof: false,
            last_activity: Instant::now(),
        })
    }

    /// A request or response is mid-flight (used to decide what shutdown
    /// drain must wait for; idle keep-alive connections are simply closed).
    fn has_pending(&self) -> bool {
        !self.buf.is_empty() || self.out_pos < self.out.len()
    }

    /// One readiness turn: read what's available, serve complete requests,
    /// flush what fits. Returns false when the connection should be dropped.
    fn pump(&mut self, router: &Router, req: &mut Request, now: Instant, progress: &mut bool) -> bool {
        // ---- read ----
        if !self.close_after {
            let mut tmp = [0u8; 8192];
            loop {
                match self.stream.read(&mut tmp) {
                    Ok(0) => {
                        self.eof = true;
                        break;
                    }
                    Ok(n) => {
                        self.buf.extend_from_slice(&tmp[..n]);
                        self.last_activity = now;
                        *progress = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => return false,
                }
            }
            // ---- parse + serve as many complete requests as buffered ----
            while !self.close_after {
                match self.try_take_request(req) {
                    TakeOutcome::Ready { close } => {
                        let resp = router.dispatch(req);
                        resp.encode_into(close, &mut self.out);
                        if close {
                            self.close_after = true;
                        }
                        self.last_activity = now;
                        *progress = true;
                    }
                    TakeOutcome::Incomplete => {
                        if self.eof && !self.buf.is_empty() {
                            // peer hung up mid-request
                            Response::bad_request("truncated request\n")
                                .encode_into(true, &mut self.out);
                            self.close_after = true;
                            self.buf.clear();
                        }
                        break;
                    }
                    TakeOutcome::Reject(resp) => {
                        resp.encode_into(true, &mut self.out);
                        self.close_after = true;
                        self.buf.clear();
                    }
                }
            }
        }
        // ---- write ----
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => return false,
                Ok(n) => {
                    self.out_pos += n;
                    self.last_activity = now;
                    *progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.out_pos >= self.out.len() {
            self.out.clear();
            self.out_pos = 0;
            if self.close_after {
                return false;
            }
        }
        if self.eof && self.buf.is_empty() && self.out.is_empty() {
            return false;
        }
        now.duration_since(self.last_activity) <= IDLE_TIMEOUT
    }

    /// Try to carve one complete request off the front of `buf` into `req`
    /// (fields refilled in place — steady state allocates nothing).
    fn try_take_request(&mut self, req: &mut Request) -> TakeOutcome {
        let Some(body_start) = find_header_end(&self.buf, self.scan_from) else {
            if self.buf.len() > MAX_HEADER_BYTES {
                return TakeOutcome::Reject(Response::bad_request("header block too large\n"));
            }
            self.scan_from = self.buf.len().saturating_sub(3);
            return TakeOutcome::Incomplete;
        };
        let Ok(head) = std::str::from_utf8(&self.buf[..body_start]) else {
            return TakeOutcome::Reject(Response::bad_request("invalid utf-8 in headers\n"));
        };
        let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("");
        let target = parts.next().unwrap_or("/");
        let version = parts.next().unwrap_or("HTTP/1.1");
        let mut content_length = 0usize;
        // HTTP/1.0 defaults to close unless keep-alive is asked for
        let mut close = version == "HTTP/1.0";
        for line in lines {
            if let Some((name, value)) = line.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap_or(0);
                } else if name.eq_ignore_ascii_case("connection") {
                    let v = value.trim();
                    if v.eq_ignore_ascii_case("close") {
                        close = true;
                    } else if v.eq_ignore_ascii_case("keep-alive") {
                        close = false;
                    }
                }
            }
        }
        if content_length > MAX_BODY_BYTES {
            return TakeOutcome::Reject(Response::payload_too_large(content_length));
        }
        let total = body_start + content_length;
        if self.buf.len() < total {
            return TakeOutcome::Incomplete;
        }
        req.method.clear();
        req.method.push_str(method);
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p, q),
            None => (target, ""),
        };
        req.path.clear();
        req.path.push_str(path);
        req.query.clear();
        req.query.push_str(query);
        req.params.clear();
        req.body.clear();
        match std::str::from_utf8(&self.buf[body_start..total]) {
            Ok(s) => req.body.push_str(s),
            Err(_) => req
                .body
                .push_str(&String::from_utf8_lossy(&self.buf[body_start..total])),
        }
        self.buf.drain(..total);
        self.scan_from = 0;
        TakeOutcome::Ready { close }
    }
}

enum TakeOutcome {
    Ready { close: bool },
    Incomplete,
    Reject(Response),
}

/// Find the end of the header block (index just past the blank line).
/// Accepts both CRLF and bare-LF line endings, like the BufReader-based
/// parser this replaces.
fn find_header_end(buf: &[u8], from: usize) -> Option<usize> {
    let mut i = from;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf.get(i + 1) == Some(&b'\n') {
                return Some(i + 2);
            }
            if buf.get(i + 1) == Some(&b'\r') && buf.get(i + 2) == Some(&b'\n') {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

/// Running server handle.
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on `addr` (e.g. "127.0.0.1:0" for an ephemeral port)
    /// with `workers` handler threads.
    pub fn start(addr: &str, router: Router, workers: usize) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let router = Arc::new(router);
        let n = workers.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut worker_threads = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel::<TcpStream>();
            txs.push(tx);
            let router = router.clone();
            worker_threads.push(std::thread::spawn(move || worker_loop(rx, &router)));
        }
        let stop2 = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            // Blocking accept: zero CPU while idle. `shutdown` wakes this
            // thread with a loopback connection after setting the stop flag.
            let mut next = 0usize;
            for res in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match res {
                    Ok(s) => {
                        if txs[next % txs.len()].send(s).is_err() {
                            break;
                        }
                        next += 1;
                    }
                    Err(_) => {
                        // transient accept failure (EMFILE etc.): back off
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
            // txs drop here → workers drain in-flight work and exit
        });
        Ok(HttpServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            workers: worker_threads,
        })
    }

    /// Stop accepting, then join the accept thread *and* every worker.
    /// Event-driven: the accept thread is woken by a loopback connection,
    /// the workers by their channel disconnecting; in-flight requests get a
    /// short drain grace, idle keep-alive connections are closed.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr); // wake the blocking accept
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
    }
}

/// Worker event loop: block on the intake channel while no connections are
/// held (idle = no CPU); with live connections, sweep them for readiness and
/// park briefly (escalating up to 1 ms) when nothing moved.
fn worker_loop(rx: mpsc::Receiver<TcpStream>, router: &Router) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut req = Request::empty();
    let mut backoff_us: u64 = 0;
    let mut draining_since: Option<Instant> = None;
    loop {
        // intake
        if conns.is_empty() {
            if draining_since.is_some() {
                return;
            }
            match rx.recv() {
                Ok(s) => add_conn(&mut conns, s),
                Err(_) => return,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(s) => add_conn(&mut conns, s),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    draining_since.get_or_insert_with(Instant::now);
                    break;
                }
            }
        }
        // readiness sweep
        let mut progress = false;
        let now = Instant::now();
        conns.retain_mut(|c| c.pump(router, &mut req, now, &mut progress));
        if let Some(t0) = draining_since {
            conns.retain(Conn::has_pending);
            if conns.is_empty() || t0.elapsed() > DRAIN_GRACE {
                return;
            }
        }
        if conns.is_empty() {
            continue;
        }
        if progress {
            backoff_us = 0;
            continue;
        }
        backoff_us = (backoff_us.max(25) * 2).min(1000);
        if draining_since.is_some() {
            std::thread::sleep(Duration::from_micros(backoff_us));
        } else {
            match rx.recv_timeout(Duration::from_micros(backoff_us)) {
                Ok(s) => add_conn(&mut conns, s),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    draining_since.get_or_insert_with(Instant::now);
                }
            }
        }
    }
}

fn add_conn(conns: &mut Vec<Conn>, stream: TcpStream) {
    let Ok(mut c) = Conn::new(stream) else { return };
    if conns.len() >= MAX_CONNS_PER_WORKER {
        Response::with_status(503, "connection limit reached\n").encode_into(true, &mut c.out);
        c.close_after = true;
    }
    conns.push(c);
}

/// Keep-alive HTTP/1.1 client: one blocking connection, many requests.
/// Responses are framed by Content-Length (which this server always sends),
/// so the connection stays open between calls — the hot-path client for the
/// bulk apply CLI, the many-tenant e2e test, and perf_serve.
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
    addr: std::net::SocketAddr,
}

/// Capped exponential backoff schedule for client retries: 10 ms doubling
/// to a 500 ms ceiling. Kept short — retries guard against transient
/// connect/IO hiccups (a leader still binding, a connection shed under an
/// apply storm), not against a leader that is down.
fn retry_backoff(delay: &mut Duration) {
    std::thread::sleep(*delay);
    *delay = (*delay * 2).min(Duration::from_millis(500));
}

impl HttpClient {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(HttpClient { stream, buf: Vec::new(), addr: *addr })
    }

    /// Connect with up to `attempts` tries, sleeping a capped exponential
    /// backoff between failures — the bulk-apply client's defence against a
    /// leader that has not finished binding its socket yet (DESIGN.md §13).
    pub fn connect_retry(
        addr: &std::net::SocketAddr,
        attempts: u32,
    ) -> std::io::Result<HttpClient> {
        let attempts = attempts.max(1);
        let mut delay = Duration::from_millis(10);
        let mut tries = 0;
        loop {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    tries += 1;
                    if tries >= attempts {
                        return Err(e);
                    }
                    retry_backoff(&mut delay);
                }
            }
        }
    }

    /// One exchange with transient-failure retry: an IO error (connection
    /// reset, truncated response) tears down the connection, reconnects and
    /// retries with capped exponential backoff. Only safe for idempotent
    /// requests — the bulk `opd apply` path is PUT — since a request that
    /// errored mid-flight may already have been executed. HTTP-level errors
    /// come back as statuses and are never retried.
    pub fn request_with_retry(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        attempts: u32,
    ) -> std::io::Result<(u16, String)> {
        let attempts = attempts.max(1);
        let mut delay = Duration::from_millis(10);
        let mut tries = 0;
        loop {
            match self.request(method, path, body) {
                Ok(r) => return Ok(r),
                Err(e) => {
                    tries += 1;
                    if tries >= attempts {
                        return Err(e);
                    }
                    retry_backoff(&mut delay);
                    // the old stream may be half-open with a poisoned read
                    // buffer; a reconnect failure leaves it in place so the
                    // next attempt errors fast and burns a try
                    if let Ok(fresh) = Self::connect(&self.addr) {
                        *self = fresh;
                    }
                }
            }
        }
    }

    /// One request/response exchange on the persistent connection.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<(u16, String)> {
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        // read the response: headers, then exactly Content-Length body bytes
        let mut tmp = [0u8; 8192];
        let header_end = loop {
            if let Some(e) = find_header_end(&self.buf, 0) {
                break e;
            }
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed before response headers",
                ));
            }
            self.buf.extend_from_slice(&tmp[..n]);
        };
        let head_text = String::from_utf8_lossy(&self.buf[..header_end]).into_owned();
        let status: u16 = head_text
            .split_whitespace()
            .nth(1)
            .and_then(|x| x.parse().ok())
            .unwrap_or(0);
        let mut content_length = 0usize;
        for line in head_text.split('\n').map(|l| l.trim_end_matches('\r')) {
            if let Some((name, value)) = line.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap_or(0);
                }
            }
        }
        let total = header_end + content_length;
        while self.buf.len() < total {
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed mid-body",
                ));
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
        let resp_body = String::from_utf8_lossy(&self.buf[header_end..total]).into_owned();
        self.buf.drain(..total);
        Ok((status, resp_body))
    }

    pub fn get(&mut self, path: &str) -> std::io::Result<(u16, String)> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &str) -> std::io::Result<(u16, String)> {
        self.request("POST", path, Some(body))
    }

    pub fn put(&mut self, path: &str, body: &str) -> std::io::Result<(u16, String)> {
        self.request("PUT", path, Some(body))
    }

    pub fn delete(&mut self, path: &str) -> std::io::Result<(u16, String)> {
        self.request("DELETE", path, None)
    }
}

/// Tiny one-shot client helper (tests, CLI health checks and the `opd apply`
/// client): Connection: close, reads to EOF.
pub fn http_request(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> std::io::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let status: u16 =
        buf.split_whitespace().nth(1).and_then(|x| x.parse().ok()).unwrap_or(0);
    let resp_body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, resp_body))
}

pub fn http_get(addr: &std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    http_request(addr, "GET", path, None)
}

pub fn http_post(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    http_request(addr, "POST", path, Some(body))
}

pub fn http_put(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    http_request(addr, "PUT", path, Some(body))
}

pub fn http_delete(addr: &std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    http_request(addr, "DELETE", path, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_and_post_roundtrip() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        router.post("/echo", |req| Response::ok(req.body.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let addr = server.addr;

        let (code, body) = http_get(&addr, "/ping").unwrap();
        assert_eq!((code, body.as_str()), (200, "pong"));

        let (code, body) = http_post(&addr, "/echo", "hello world").unwrap();
        assert_eq!((code, body.as_str()), (200, "hello world"));

        let (code, _) = http_get(&addr, "/missing").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn query_strings_are_split() {
        let mut router = Router::new();
        router.get("/q", |req| Response::ok(req.query.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let (code, body) = http_get(&server.addr, "/q?a=1&b=2").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "a=1&b=2");
        server.shutdown();
    }

    #[test]
    fn concurrent_requests() {
        let mut router = Router::new();
        router.get("/x", |_| Response::ok("y"));
        let server = HttpServer::start("127.0.0.1:0", router, 4).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(move || http_get(&addr, "/x").unwrap().0))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
        server.shutdown();
    }

    #[test]
    fn path_params_are_captured() {
        let mut router = Router::new();
        router.get("/v1/pipelines/{name}", |req| Response::ok(req.param("name").to_string()));
        router.post("/v1/pipelines/{name}/agent", |req| {
            Response::ok(format!("{}:{}", req.param("name"), req.body))
        });
        router.get("/v1/pipelines", |_| Response::ok("list"));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let addr = server.addr;

        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!((code, body.as_str()), (200, "vid"));
        let (code, body) = http_post(&addr, "/v1/pipelines/iot/agent", "ipa").unwrap();
        assert_eq!((code, body.as_str()), (200, "iot:ipa"));
        // exact route still wins over the pattern space
        let (code, body) = http_get(&addr, "/v1/pipelines").unwrap();
        assert_eq!((code, body.as_str()), (200, "list"));
        // unmatched depth → 404
        let (code, _) = http_get(&addr, "/v1/pipelines/a/b/c").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn wrong_method_is_405_not_404() {
        let mut router = Router::new();
        router.get("/only-get", |_| Response::ok("x"));
        router.put("/v1/pipelines/{name}", |_| Response::ok("put"));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let addr = server.addr;

        let (code, _) = http_post(&addr, "/only-get", "").unwrap();
        assert_eq!(code, 405);
        let (code, _) = http_get(&addr, "/v1/pipelines/x").unwrap();
        assert_eq!(code, 405);
        let (code, _) = http_get(&addr, "/never-registered").unwrap();
        assert_eq!(code, 404);
        server.shutdown();
    }

    #[test]
    fn oversize_body_is_rejected_with_413() {
        let mut router = Router::new();
        router.post("/sink", |req| Response::ok(format!("{}", req.body.len())));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        // declare a body over the cap without sending it: the server must
        // answer 413 instead of truncating at 1 MiB and dispatching
        let mut s = TcpStream::connect(server.addr).unwrap();
        let head = format!(
            "POST /sink HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        s.write_all(head.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let status: u16 =
            buf.split_whitespace().nth(1).and_then(|x| x.parse().ok()).unwrap_or(0);
        assert_eq!(status, 413, "{buf}");
        // a body at the cap still works
        let body = "x".repeat(1024);
        let (code, got) = http_post(&server.addr, "/sink", &body).unwrap();
        assert_eq!((code, got.as_str()), (200, "1024"));
        server.shutdown();
    }

    #[test]
    fn put_and_delete_roundtrip() {
        let mut router = Router::new();
        router.put("/thing/{id}", |req| {
            Response::json_with_status(201, format!("{{\"id\":\"{}\"}}", req.param("id")))
        });
        router.delete("/thing/{id}", |req| Response::ok(req.param("id").to_string()));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let (code, body) = http_put(&server.addr, "/thing/42", "{}").unwrap();
        assert_eq!((code, body.as_str()), (201, "{\"id\":\"42\"}"));
        let (code, body) = http_delete(&server.addr, "/thing/42").unwrap();
        assert_eq!((code, body.as_str()), (200, "42"));
        server.shutdown();
    }

    #[test]
    fn keep_alive_connection_serves_many_requests() {
        let mut router = Router::new();
        router.get("/n/{i}", |req| Response::ok(req.param("i").to_string()));
        router.post("/echo", |req| Response::ok(req.body.clone()));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let mut client = HttpClient::connect(&server.addr).unwrap();
        for i in 0..100 {
            let (code, body) = client.get(&format!("/n/{i}")).unwrap();
            assert_eq!((code, body.as_str()), (200, format!("{i}").as_str()));
            let payload = format!("payload-{i}");
            let (code, body) = client.post("/echo", &payload).unwrap();
            assert_eq!((code, body), (200, payload));
        }
        // the one-shot close-mode client still works alongside
        let (code, _) = http_get(&server.addr, "/n/7").unwrap();
        assert_eq!(code, 200);
        server.shutdown();
    }

    #[test]
    fn pipelined_requests_are_served_in_order() {
        let mut router = Router::new();
        router.get("/a", |_| Response::ok("first"));
        router.get("/b", |_| Response::ok("second"));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(
            b"GET /a HTTP/1.1\r\nHost: x\r\n\r\nGET /b HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let first = buf.find("first").expect("first response present");
        let second = buf.find("second").expect("second response present");
        assert!(first < second, "responses out of order: {buf}");
        assert_eq!(buf.matches("HTTP/1.1 200").count(), 2);
        server.shutdown();
    }

    #[test]
    fn shutdown_returns_with_idle_keepalive_connection_open() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let mut client = HttpClient::connect(&server.addr).unwrap();
        let (code, _) = client.get("/ping").unwrap();
        assert_eq!(code, 200);
        // the connection stays open and idle; shutdown must not hang on it
        let t0 = Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown hung on an idle keep-alive connection"
        );
    }

    #[test]
    fn request_with_retry_survives_a_dropped_connection() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        router.put("/thing/{id}", |req| Response::ok(req.param("id").to_string()));
        let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
        let mut client = HttpClient::connect(&server.addr).unwrap();
        assert_eq!(client.get("/ping").unwrap().0, 200);
        // sever the connection under the client: the plain path errors out,
        // the retrying path reconnects to the still-running server
        client.stream.shutdown(std::net::Shutdown::Both).unwrap();
        assert!(client.request("GET", "/ping", None).is_err());
        let (code, body) = client.request_with_retry("PUT", "/thing/7", Some("{}"), 4).unwrap();
        assert_eq!((code, body.as_str()), (200, "7"));
        // and the healed connection keeps serving without retries
        assert_eq!(client.get("/ping").unwrap().0, 200);
        server.shutdown();
    }

    #[test]
    fn connect_retry_gives_up_after_its_attempts() {
        // a bound-then-dropped listener port refuses connections quickly
        let addr = TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap();
        let t0 = Instant::now();
        assert!(HttpClient::connect_retry(&addr, 3).is_err());
        // 2 sleeps of the 10 ms-doubling schedule: ~30 ms, well under a second
        assert!(t0.elapsed() < Duration::from_secs(2));
        // attempts are clamped to at least one try
        assert!(HttpClient::connect_retry(&addr, 0).is_err());
    }

    #[test]
    fn connect_retry_succeeds_against_a_live_server() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let mut client = HttpClient::connect_retry(&server.addr, 5).unwrap();
        assert_eq!(client.get("/ping").unwrap().0, 200);
        server.shutdown();
    }

    #[test]
    fn http10_defaults_to_close() {
        let mut router = Router::new();
        router.get("/ping", |_| Response::ok("pong"));
        let server = HttpServer::start("127.0.0.1:0", router, 1).unwrap();
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(b"GET /ping HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap(); // EOF proves the server closed
        assert!(buf.contains("HTTP/1.1 200"), "{buf}");
        assert!(buf.to_ascii_lowercase().contains("connection: close"), "{buf}");
        server.shutdown();
    }
}
