//! v1 control-plane REST API: typed request/response structs plus the router
//! wiring. HTTP handlers never touch the simulation directly — the sim/agent
//! state is single-threaded by design (the PJRT runtime is not Sync) — they
//! translate HTTP into `ControlRequest`s sent over a channel to the `Leader`
//! loop and block on its typed reply. The same pattern as the paper's
//! Kubernetes API server fronting a single controller loop.
//!
//! Surface:
//!   GET    /v1/pipelines               list deployed pipelines
//!   POST   /v1/pipelines               create (409 when the name exists)
//!   GET    /v1/pipelines/{name}        status of one pipeline
//!   PUT    /v1/pipelines/{name}        declaratively apply (create-or-update)
//!   DELETE /v1/pipelines/{name}        remove, releasing its cluster share
//!   POST   /v1/pipelines/{name}/agent  hot-swap the decision agent
//!   GET    /v1/cluster                 nodes + shared-capacity accounting
//!   POST   /v1/shutdown                stop the leader loop
//! plus the classic observability routes (/metrics /state /series /healthz).

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::AgentKind;
use crate::pipeline::{TaskConfig, BATCH_CHOICES};
use crate::serve::http::{Request, Response, Router};
use crate::serve::ControlPlane;
use crate::util::json::Json;
use crate::workload::WorkloadKind;

/// Typed API error → HTTP status + `{"error": …}` body.
#[derive(Clone, Debug)]
pub struct ApiError {
    pub status: u16,
    pub message: String,
}

impl ApiError {
    pub fn bad_request(message: impl Into<String>) -> Self {
        Self { status: 400, message: message.into() }
    }

    pub fn not_found(message: impl Into<String>) -> Self {
        Self { status: 404, message: message.into() }
    }

    pub fn conflict(message: impl Into<String>) -> Self {
        Self { status: 409, message: message.into() }
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self { status: 500, message: message.into() }
    }
}

/// Declarative pipeline deployment spec — the POST/PUT /v1/pipelines body.
#[derive(Clone, Debug)]
pub struct DeploySpec {
    /// deployment name (the key on the shared cluster)
    pub name: String,
    /// catalog pipeline (P1..P4, video-analytics, iot-anomaly)
    pub pipeline: String,
    pub workload: WorkloadKind,
    pub agent: AgentKind,
    pub adapt_interval_secs: usize,
    pub seed: u64,
    /// optional explicit initial config (cheapest config when None)
    pub initial: Option<Vec<TaskConfig>>,
}

impl DeploySpec {
    /// Parse a deploy spec from JSON. `path_name`, when given (PUT/DELETE
    /// routes), wins over any "name" field in the body.
    pub fn from_json(j: &Json, path_name: Option<&str>) -> Result<DeploySpec, String> {
        let name = match path_name {
            Some(n) => n.to_string(),
            None => j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("missing field 'name'")?
                .to_string(),
        };
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Err(format!("invalid pipeline name '{name}' (use [A-Za-z0-9_-]+)"));
        }
        let pipeline = j
            .get("pipeline")
            .and_then(Json::as_str)
            .ok_or("missing field 'pipeline'")?
            .to_string();
        let workload = match j.get("workload").and_then(Json::as_str) {
            Some(w) => WorkloadKind::from_name(w).ok_or(format!("unknown workload '{w}'"))?,
            None => WorkloadKind::Fluctuating,
        };
        let agent = match j.get("agent").and_then(Json::as_str) {
            Some(a) => AgentKind::from_name(a).ok_or(format!(
                "unknown agent '{a}' (available: {})",
                AgentKind::available().join(", ")
            ))?,
            None => AgentKind::Greedy,
        };
        let adapt_interval_secs =
            j.get("adapt_interval_secs").and_then(Json::as_usize).unwrap_or(10);
        if adapt_interval_secs == 0 {
            return Err("adapt_interval_secs must be >= 1".into());
        }
        let seed = j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(42);
        let initial = match j.get("config") {
            Some(Json::Arr(items)) => Some(
                items
                    .iter()
                    .map(task_config_from_json)
                    .collect::<Result<Vec<TaskConfig>, String>>()?,
            ),
            Some(_) => return Err("'config' must be an array of task configs".into()),
            None => None,
        };
        Ok(DeploySpec { name, pipeline, workload, agent, adapt_interval_secs, seed, initial })
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("pipeline", self.pipeline.as_str())
            .set("workload", self.workload.name())
            .set("agent", self.agent.name())
            .set("adapt_interval_secs", self.adapt_interval_secs)
            .set("seed", self.seed as i64);
        if let Some(cfgs) = &self.initial {
            j = j.set("config", Json::Arr(cfgs.iter().map(task_config_json).collect()));
        }
        j
    }
}

fn task_config_from_json(j: &Json) -> Result<TaskConfig, String> {
    let batch_idx = match j.get("batch") {
        Some(b) => {
            let b = b.as_usize().ok_or("'batch' must be an integer")?;
            BATCH_CHOICES
                .iter()
                .position(|&x| x == b)
                .ok_or(format!("batch {b} not one of {BATCH_CHOICES:?}"))?
        }
        None => j.get("batch_idx").and_then(Json::as_usize).unwrap_or(0),
    };
    Ok(TaskConfig {
        variant: j.get("variant").and_then(Json::as_usize).unwrap_or(0),
        replicas: j.get("replicas").and_then(Json::as_usize).unwrap_or(1),
        batch_idx,
    })
}

/// JSON view of one task configuration (batch serialized as the real size).
pub fn task_config_json(c: &TaskConfig) -> Json {
    Json::obj()
        .set("variant", c.variant)
        .set("replicas", c.replicas)
        .set("batch", c.batch())
}

/// Commands the HTTP face sends to the leader loop.
pub enum ControlRequest {
    ListPipelines,
    GetPipeline(String),
    /// `create_only` → POST semantics (409 when the name exists); otherwise
    /// PUT semantics (declarative create-or-update)
    ApplyPipeline { spec: DeploySpec, create_only: bool },
    DeletePipeline(String),
    SwapAgent { pipeline: String, agent: AgentKind, seed: u64 },
    GetCluster,
    Shutdown,
}

/// (status, body) reply from the leader.
pub type ControlReply = Result<(u16, Json), ApiError>;

pub struct ControlMsg {
    pub req: ControlRequest,
    pub reply: Sender<ControlReply>,
}

fn error_response(status: u16, message: &str) -> Response {
    Response::json_with_status(status, Json::obj().set("error", message).to_pretty())
}

/// Send one command to the leader and block (bounded) on its reply.
fn call(tx: &Arc<Mutex<Sender<ControlMsg>>>, req: ControlRequest) -> Response {
    let (rtx, rrx) = channel();
    let sent = tx.lock().unwrap().send(ControlMsg { req, reply: rtx }).is_ok();
    if !sent {
        return error_response(503, "leader loop is not running");
    }
    match rrx.recv_timeout(Duration::from_secs(10)) {
        Ok(Ok((status, body))) => Response::json_with_status(status, body.to_pretty()),
        Ok(Err(e)) => error_response(e.status, &e.message),
        Err(_) => error_response(504, "leader did not answer in time"),
    }
}

fn parse_body(req: &Request) -> Result<Json, Response> {
    Json::parse(&req.body).map_err(|e| error_response(400, &format!("invalid JSON body: {e}")))
}

/// Build the leader's full router: classic observability endpoints plus the
/// versioned v1 control-plane API backed by `tx`.
pub fn v1_router(cp: &Arc<ControlPlane>, tx: Sender<ControlMsg>) -> Router {
    let mut router = cp.base_router();
    let tx = Arc::new(Mutex::new(tx));

    let t = tx.clone();
    router.get("/v1/pipelines", move |_| call(&t, ControlRequest::ListPipelines));

    let t = tx.clone();
    router.post("/v1/pipelines", move |req| match parse_body(req) {
        Ok(j) => match DeploySpec::from_json(&j, None) {
            Ok(spec) => call(&t, ControlRequest::ApplyPipeline { spec, create_only: true }),
            Err(e) => error_response(400, &e),
        },
        Err(resp) => resp,
    });

    let t = tx.clone();
    router.get("/v1/pipelines/{name}", move |req| {
        call(&t, ControlRequest::GetPipeline(req.param("name").to_string()))
    });

    let t = tx.clone();
    router.put("/v1/pipelines/{name}", move |req| match parse_body(req) {
        Ok(j) => match DeploySpec::from_json(&j, Some(req.param("name"))) {
            Ok(spec) => call(&t, ControlRequest::ApplyPipeline { spec, create_only: false }),
            Err(e) => error_response(400, &e),
        },
        Err(resp) => resp,
    });

    let t = tx.clone();
    router.delete("/v1/pipelines/{name}", move |req| {
        call(&t, ControlRequest::DeletePipeline(req.param("name").to_string()))
    });

    let t = tx.clone();
    router.post("/v1/pipelines/{name}/agent", move |req| {
        let j = match parse_body(req) {
            Ok(j) => j,
            Err(resp) => return resp,
        };
        let kind = match j.get("agent").and_then(Json::as_str) {
            Some(k) => k,
            None => return error_response(400, "missing field 'agent'"),
        };
        let agent = match AgentKind::from_name(kind) {
            Some(a) => a,
            None => {
                return error_response(
                    400,
                    &format!(
                        "unknown agent '{kind}' (available: {})",
                        AgentKind::available().join(", ")
                    ),
                )
            }
        };
        let seed = j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(42);
        call(
            &t,
            ControlRequest::SwapAgent {
                pipeline: req.param("name").to_string(),
                agent,
                seed,
            },
        )
    });

    let t = tx.clone();
    router.get("/v1/cluster", move |_| call(&t, ControlRequest::GetCluster));

    let t = tx.clone();
    router.post("/v1/shutdown", move |_| call(&t, ControlRequest::Shutdown));

    router
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploy_spec_parses_with_defaults() {
        let j = Json::parse(r#"{"name":"vid","pipeline":"video-analytics"}"#).unwrap();
        let s = DeploySpec::from_json(&j, None).unwrap();
        assert_eq!(s.name, "vid");
        assert_eq!(s.pipeline, "video-analytics");
        assert_eq!(s.workload, WorkloadKind::Fluctuating);
        assert_eq!(s.agent, AgentKind::Greedy);
        assert_eq!(s.adapt_interval_secs, 10);
        assert!(s.initial.is_none());
    }

    #[test]
    fn deploy_spec_full_roundtrip() {
        let j = Json::parse(
            r#"{"name":"x","pipeline":"P2","workload":"steady-high","agent":"ipa",
                "adapt_interval_secs":5,"seed":9,
                "config":[{"variant":1,"replicas":2,"batch":4}]}"#,
        )
        .unwrap();
        let s = DeploySpec::from_json(&j, None).unwrap();
        assert_eq!(s.agent, AgentKind::Ipa);
        assert_eq!(s.workload, WorkloadKind::SteadyHigh);
        assert_eq!(s.adapt_interval_secs, 5);
        let cfg = &s.initial.as_ref().unwrap()[0];
        assert_eq!((cfg.variant, cfg.replicas, cfg.batch()), (1, 2, 4));
        // serialize → reparse is stable
        let back = DeploySpec::from_json(&s.to_json(), None).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.initial.as_ref().unwrap()[0], s.initial.as_ref().unwrap()[0]);
    }

    #[test]
    fn path_name_wins_over_body_name() {
        let j = Json::parse(r#"{"name":"body","pipeline":"P1"}"#).unwrap();
        let s = DeploySpec::from_json(&j, Some("path")).unwrap();
        assert_eq!(s.name, "path");
        // and the body may omit name entirely on PUT
        let j = Json::parse(r#"{"pipeline":"P1"}"#).unwrap();
        assert!(DeploySpec::from_json(&j, Some("p")).is_ok());
        assert!(DeploySpec::from_json(&j, None).is_err());
    }

    #[test]
    fn deploy_spec_rejects_bad_values() {
        for body in [
            r#"{"pipeline":"P1"}"#,
            r#"{"name":"a b","pipeline":"P1"}"#,
            r#"{"name":"a","pipeline":"P1","workload":"nope"}"#,
            r#"{"name":"a","pipeline":"P1","agent":"nope"}"#,
            r#"{"name":"a","pipeline":"P1","adapt_interval_secs":0}"#,
            r#"{"name":"a","pipeline":"P1","config":[{"batch":3}]}"#,
            r#"{"name":"a","pipeline":"P1","config":{}}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert!(DeploySpec::from_json(&j, None).is_err(), "{body}");
        }
    }
}
