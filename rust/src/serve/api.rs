//! v1 control-plane REST API: typed request/response structs plus the router
//! wiring. HTTP handlers never touch the simulation directly — the sim/agent
//! state is owned by the leader loop, one writer by design — they
//! translate HTTP into `ControlRequest`s sent over a channel to the `Leader`
//! loop and block on its typed reply. The same pattern as the paper's
//! Kubernetes API server fronting a single controller loop.
//!
//! Surface:
//!   GET    /v1/pipelines               list deployed pipelines
//!   POST   /v1/pipelines               create (409 when the name exists)
//!   GET    /v1/pipelines/{name}        status of one pipeline
//!   PUT    /v1/pipelines/{name}        declaratively apply (create-or-update)
//!   DELETE /v1/pipelines/{name}        remove, releasing its cluster share
//!   POST   /v1/pipelines/{name}/agent  hot-swap the decision agent
//!   GET    /v1/cluster                 nodes + shared-capacity accounting
//!   POST   /v1/chaos                   schedule a fault-injection plan
//!   POST   /v1/shutdown                stop the leader loop
//! plus the classic observability routes (/metrics /state /series /healthz).

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::AgentKind;
use crate::pipeline::{TaskConfig, BATCH_CHOICES};
use crate::serve::http::{Response, Router};
use crate::serve::ControlPlane;
use crate::util::json::{Json, LazyObj};
use crate::workload::WorkloadKind;

/// Typed API error → HTTP status + `{"error": …}` body.
#[derive(Clone, Debug)]
pub struct ApiError {
    pub status: u16,
    pub message: String,
}

impl ApiError {
    pub fn bad_request(message: impl Into<String>) -> Self {
        Self { status: 400, message: message.into() }
    }

    pub fn not_found(message: impl Into<String>) -> Self {
        Self { status: 404, message: message.into() }
    }

    pub fn conflict(message: impl Into<String>) -> Self {
        Self { status: 409, message: message.into() }
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self { status: 500, message: message.into() }
    }
}

/// Declarative pipeline deployment spec — the POST/PUT /v1/pipelines body.
#[derive(Clone, Debug, PartialEq)]
pub struct DeploySpec {
    /// deployment name (the key on the shared cluster)
    pub name: String,
    /// catalog pipeline (P1..P4, video-analytics, iot-anomaly)
    pub pipeline: String,
    pub workload: WorkloadKind,
    pub agent: AgentKind,
    pub adapt_interval_secs: usize,
    pub seed: u64,
    /// optional explicit initial config (cheapest config when None)
    pub initial: Option<Vec<TaskConfig>>,
}

impl DeploySpec {
    /// Parse a deploy spec from JSON. `path_name`, when given (PUT/DELETE
    /// routes), wins over any "name" field in the body.
    pub fn from_json(j: &Json, path_name: Option<&str>) -> Result<DeploySpec, String> {
        let name = match path_name {
            Some(n) => n.to_string(),
            None => j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("missing field 'name'")?
                .to_string(),
        };
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Err(format!("invalid pipeline name '{name}' (use [A-Za-z0-9_-]+)"));
        }
        let pipeline = j
            .get("pipeline")
            .and_then(Json::as_str)
            .ok_or("missing field 'pipeline'")?
            .to_string();
        let workload = match j.get("workload").and_then(Json::as_str) {
            Some(w) => WorkloadKind::from_name(w).ok_or(format!("unknown workload '{w}'"))?,
            None => WorkloadKind::Fluctuating,
        };
        let agent = match j.get("agent").and_then(Json::as_str) {
            Some(a) => AgentKind::from_name(a).ok_or(format!(
                "unknown agent '{a}' (available: {})",
                AgentKind::available().join(", ")
            ))?,
            None => AgentKind::Greedy,
        };
        let adapt_interval_secs =
            j.get("adapt_interval_secs").and_then(Json::as_usize).unwrap_or(10);
        if adapt_interval_secs == 0 {
            return Err("adapt_interval_secs must be >= 1".into());
        }
        let seed = j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(42);
        let initial = match j.get("config") {
            Some(Json::Arr(items)) => Some(
                items
                    .iter()
                    .map(task_config_from_json)
                    .collect::<Result<Vec<TaskConfig>, String>>()?,
            ),
            Some(_) => return Err("'config' must be an array of task configs".into()),
            None => None,
        };
        Ok(DeploySpec { name, pipeline, workload, agent, adapt_interval_secs, seed, initial })
    }

    /// Parse a deploy spec straight from a request body. Hot path for
    /// cluster-scale apply storms (DESIGN.md §12): a lazy top-level field
    /// scan extracts the spec without building a JSON tree. Anything
    /// ambiguous — parse failure, escaped or non-string fields, an explicit
    /// `config` — falls back to the tree parser, so errors and edge-case
    /// semantics stay byte-identical to [`DeploySpec::from_json`].
    pub fn from_body(body: &str, path_name: Option<&str>) -> Result<DeploySpec, String> {
        if let Some(fast) = Self::from_body_fast(body, path_name) {
            return fast;
        }
        let j = Json::parse(body).map_err(|e| format!("invalid JSON body: {e}"))?;
        Self::from_json(&j, path_name)
    }

    /// `None` → ambiguous, take the tree path; `Some(r)` → exactly the
    /// result `from_json` would produce for this body.
    fn from_body_fast(body: &str, path_name: Option<&str>) -> Option<Result<DeploySpec, String>> {
        // A raw value that is not a plain unescaped string is either a type
        // the tree path silently defaults on or an escaped string it
        // decodes — both need the tree parser to stay identical.
        fn plain_str<'a>(obj: &LazyObj<'a>, key: &str) -> Option<Option<&'a str>> {
            match obj.get_raw(key) {
                None => Some(None),
                Some(_) => obj.get_str(key).map(Some),
            }
        }
        let obj = LazyObj::parse(body).ok()?;
        if obj.has("config") {
            return None; // explicit initial configs take the tree path
        }
        let name = match path_name {
            Some(n) => n.to_string(),
            None => match plain_str(&obj, "name")? {
                Some(s) => s.to_string(),
                None => return Some(Err("missing field 'name'".into())),
            },
        };
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Some(Err(format!("invalid pipeline name '{name}' (use [A-Za-z0-9_-]+)")));
        }
        let pipeline = match plain_str(&obj, "pipeline")? {
            Some(s) => s.to_string(),
            None => return Some(Err("missing field 'pipeline'".into())),
        };
        let workload = match plain_str(&obj, "workload")? {
            Some(w) => match WorkloadKind::from_name(w) {
                Some(k) => k,
                None => return Some(Err(format!("unknown workload '{w}'"))),
            },
            None => WorkloadKind::Fluctuating,
        };
        let agent = match plain_str(&obj, "agent")? {
            Some(a) => match AgentKind::from_name(a) {
                Some(k) => k,
                None => {
                    return Some(Err(format!(
                        "unknown agent '{a}' (available: {})",
                        AgentKind::available().join(", ")
                    )))
                }
            },
            None => AgentKind::Greedy,
        };
        let adapt_interval_secs = obj.get_usize("adapt_interval_secs").unwrap_or(10);
        if adapt_interval_secs == 0 {
            return Some(Err("adapt_interval_secs must be >= 1".into()));
        }
        let seed = obj.get_i64("seed").map(|v| v as u64).unwrap_or(42);
        Some(Ok(DeploySpec {
            name,
            pipeline,
            workload,
            agent,
            adapt_interval_secs,
            seed,
            initial: None,
        }))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("pipeline", self.pipeline.as_str())
            .set("workload", self.workload.name())
            .set("agent", self.agent.name())
            .set("adapt_interval_secs", self.adapt_interval_secs)
            .set("seed", self.seed as i64);
        if let Some(cfgs) = &self.initial {
            j = j.set("config", Json::Arr(cfgs.iter().map(task_config_json).collect()));
        }
        j
    }
}

fn task_config_from_json(j: &Json) -> Result<TaskConfig, String> {
    let batch_idx = match j.get("batch") {
        Some(b) => {
            let b = b.as_usize().ok_or("'batch' must be an integer")?;
            BATCH_CHOICES
                .iter()
                .position(|&x| x == b)
                .ok_or(format!("batch {b} not one of {BATCH_CHOICES:?}"))?
        }
        None => j.get("batch_idx").and_then(Json::as_usize).unwrap_or(0),
    };
    Ok(TaskConfig {
        variant: j.get("variant").and_then(Json::as_usize).unwrap_or(0),
        replicas: j.get("replicas").and_then(Json::as_usize).unwrap_or(1),
        batch_idx,
    })
}

/// JSON view of one task configuration (batch serialized as the real size).
pub fn task_config_json(c: &TaskConfig) -> Json {
    Json::obj()
        .set("variant", c.variant)
        .set("replicas", c.replicas)
        .set("batch", c.batch())
}

/// Commands the HTTP face sends to the leader loop.
pub enum ControlRequest {
    ListPipelines,
    GetPipeline(String),
    /// `create_only` → POST semantics (409 when the name exists); otherwise
    /// PUT semantics (declarative create-or-update)
    ApplyPipeline { spec: DeploySpec, create_only: bool },
    DeletePipeline(String),
    SwapAgent { pipeline: String, agent: AgentKind, seed: u64 },
    GetCluster,
    /// Schedule a chaos plan (the spec grammar of `FaultPlan::parse`);
    /// events fire relative to the sim clock at arrival (DESIGN.md §13).
    Chaos(String),
    Shutdown,
}

/// (status, body) reply from the leader.
pub type ControlReply = Result<(u16, Json), ApiError>;

pub struct ControlMsg {
    pub req: ControlRequest,
    pub reply: Sender<ControlReply>,
}

fn error_response(status: u16, message: &str) -> Response {
    Response::json_with_status(status, Json::obj().set("error", message).to_pretty())
}

/// Send one command to the leader and block (bounded) on its reply.
fn call(tx: &Arc<Mutex<Sender<ControlMsg>>>, req: ControlRequest) -> Response {
    let (rtx, rrx) = channel();
    let sent = tx.lock().unwrap().send(ControlMsg { req, reply: rtx }).is_ok();
    if !sent {
        return error_response(503, "leader loop is not running");
    }
    match rrx.recv_timeout(Duration::from_secs(10)) {
        Ok(Ok((status, body))) => Response::json_with_status(status, body.to_pretty()),
        Ok(Err(e)) => error_response(e.status, &e.message),
        Err(_) => error_response(504, "leader did not answer in time"),
    }
}

/// Extract the agent hot-swap fields from `{"agent": ..., "seed": ...}`.
/// Lazy fast path with tree-parser fallback on any ambiguity, mirroring
/// `DeploySpec::from_body` (DESIGN.md §12).
fn swap_fields(body: &str) -> Result<(AgentKind, u64), Response> {
    if let Some(fast) = swap_fields_fast(body) {
        return fast;
    }
    let j = Json::parse(body)
        .map_err(|e| error_response(400, &format!("invalid JSON body: {e}")))?;
    let kind = j
        .get("agent")
        .and_then(Json::as_str)
        .ok_or_else(|| error_response(400, "missing field 'agent'"))?;
    let agent = AgentKind::from_name(kind).ok_or_else(|| {
        error_response(
            400,
            &format!("unknown agent '{kind}' (available: {})", AgentKind::available().join(", ")),
        )
    })?;
    let seed = j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(42);
    Ok((agent, seed))
}

/// `None` → ambiguous (bad JSON / escaped or non-string agent), take the
/// tree path; `Some(r)` → exactly what the tree path would produce.
fn swap_fields_fast(body: &str) -> Option<Result<(AgentKind, u64), Response>> {
    let obj = LazyObj::parse(body).ok()?;
    let kind = match obj.get_raw("agent") {
        None => return Some(Err(error_response(400, "missing field 'agent'"))),
        Some(_) => obj.get_str("agent")?,
    };
    let agent = match AgentKind::from_name(kind) {
        Some(a) => a,
        None => {
            return Some(Err(error_response(
                400,
                &format!(
                    "unknown agent '{kind}' (available: {})",
                    AgentKind::available().join(", ")
                ),
            )))
        }
    };
    let seed = obj.get_i64("seed").map(|v| v as u64).unwrap_or(42);
    Some(Ok((agent, seed)))
}

/// Build the leader's full router: classic observability endpoints plus the
/// versioned v1 control-plane API backed by `tx`.
pub fn v1_router(cp: &Arc<ControlPlane>, tx: Sender<ControlMsg>) -> Router {
    let mut router = cp.base_router();
    let tx = Arc::new(Mutex::new(tx));

    let t = tx.clone();
    router.get("/v1/pipelines", move |_| call(&t, ControlRequest::ListPipelines));

    let t = tx.clone();
    router.post("/v1/pipelines", move |req| match DeploySpec::from_body(&req.body, None) {
        Ok(spec) => call(&t, ControlRequest::ApplyPipeline { spec, create_only: true }),
        Err(e) => error_response(400, &e),
    });

    let t = tx.clone();
    router.get("/v1/pipelines/{name}", move |req| {
        call(&t, ControlRequest::GetPipeline(req.param("name").to_string()))
    });

    let t = tx.clone();
    router.put("/v1/pipelines/{name}", move |req| {
        match DeploySpec::from_body(&req.body, Some(req.param("name"))) {
            Ok(spec) => call(&t, ControlRequest::ApplyPipeline { spec, create_only: false }),
            Err(e) => error_response(400, &e),
        }
    });

    let t = tx.clone();
    router.delete("/v1/pipelines/{name}", move |req| {
        call(&t, ControlRequest::DeletePipeline(req.param("name").to_string()))
    });

    let t = tx.clone();
    router.post("/v1/pipelines/{name}/agent", move |req| {
        let (agent, seed) = match swap_fields(&req.body) {
            Ok(x) => x,
            Err(resp) => return resp,
        };
        call(
            &t,
            ControlRequest::SwapAgent {
                pipeline: req.param("name").to_string(),
                agent,
                seed,
            },
        )
    });

    let t = tx.clone();
    router.get("/v1/cluster", move |_| call(&t, ControlRequest::GetCluster));

    let t = tx.clone();
    router.post("/v1/chaos", move |req| {
        // chaos injection is rare — the tree parser is fine here
        let plan = match Json::parse(&req.body) {
            Ok(j) => match j.get("plan").and_then(Json::as_str) {
                Some(p) => p.to_string(),
                None => return error_response(400, "missing field 'plan'"),
            },
            Err(e) => return error_response(400, &format!("invalid JSON body: {e}")),
        };
        call(&t, ControlRequest::Chaos(plan))
    });

    let t = tx.clone();
    router.post("/v1/shutdown", move |_| call(&t, ControlRequest::Shutdown));

    router
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploy_spec_parses_with_defaults() {
        let j = Json::parse(r#"{"name":"vid","pipeline":"video-analytics"}"#).unwrap();
        let s = DeploySpec::from_json(&j, None).unwrap();
        assert_eq!(s.name, "vid");
        assert_eq!(s.pipeline, "video-analytics");
        assert_eq!(s.workload, WorkloadKind::Fluctuating);
        assert_eq!(s.agent, AgentKind::Greedy);
        assert_eq!(s.adapt_interval_secs, 10);
        assert!(s.initial.is_none());
    }

    #[test]
    fn deploy_spec_full_roundtrip() {
        let j = Json::parse(
            r#"{"name":"x","pipeline":"P2","workload":"steady-high","agent":"ipa",
                "adapt_interval_secs":5,"seed":9,
                "config":[{"variant":1,"replicas":2,"batch":4}]}"#,
        )
        .unwrap();
        let s = DeploySpec::from_json(&j, None).unwrap();
        assert_eq!(s.agent, AgentKind::Ipa);
        assert_eq!(s.workload, WorkloadKind::SteadyHigh);
        assert_eq!(s.adapt_interval_secs, 5);
        let cfg = &s.initial.as_ref().unwrap()[0];
        assert_eq!((cfg.variant, cfg.replicas, cfg.batch()), (1, 2, 4));
        // serialize → reparse is stable
        let back = DeploySpec::from_json(&s.to_json(), None).unwrap();
        assert_eq!(back.name, s.name);
        assert_eq!(back.initial.as_ref().unwrap()[0], s.initial.as_ref().unwrap()[0]);
    }

    #[test]
    fn path_name_wins_over_body_name() {
        let j = Json::parse(r#"{"name":"body","pipeline":"P1"}"#).unwrap();
        let s = DeploySpec::from_json(&j, Some("path")).unwrap();
        assert_eq!(s.name, "path");
        // and the body may omit name entirely on PUT
        let j = Json::parse(r#"{"pipeline":"P1"}"#).unwrap();
        assert!(DeploySpec::from_json(&j, Some("p")).is_ok());
        assert!(DeploySpec::from_json(&j, None).is_err());
    }

    /// The lazy fast path must be observationally identical to the tree
    /// path — same specs, same error strings — across representative v1
    /// bodies (fast-path hits, bail-outs, and errors alike).
    #[test]
    fn from_body_matches_the_tree_parser() {
        let corpus = [
            r#"{"name":"vid","pipeline":"video-analytics"}"#,
            r#"{"name":"x","pipeline":"P2","workload":"steady-high","agent":"ipa","adapt_interval_secs":5,"seed":9}"#,
            // escaped string → bails to the tree path, which decodes it
            r#"{"name":"a\u0062c","pipeline":"P1"}"#,
            // duplicate key: last one wins on both paths
            r#"{"name":"a","name":"b","pipeline":"P1"}"#,
            // non-string / fractional typed fields → tree-path defaults
            r#"{"name":"a","pipeline":"P1","agent":7}"#,
            r#"{"name":"a","pipeline":"P1","seed":1.5}"#,
            r#"{"name":"a","pipeline":"P1","adapt_interval_secs":-3}"#,
            // explicit config → always the tree path
            r#"{"name":"a","pipeline":"P1","config":[{"variant":1,"replicas":2,"batch":4}]}"#,
            r#"{"name":"a","pipeline":"P1","config":{}}"#,
            // errors must match byte for byte
            r#"{"pipeline":"P1"}"#,
            r#"{"name":"a b","pipeline":"P1"}"#,
            r#"{"name":"a","pipeline":"P1","workload":"nope"}"#,
            r#"{"name":"a","pipeline":"P1","agent":"nope"}"#,
            r#"{"name":"a","pipeline":"P1","adapt_interval_secs":0}"#,
            r#"{"name":"a""#,
            r#"[1,2,3]"#,
            r#"not json"#,
        ];
        for body in corpus {
            for path_name in [None, Some("from-path")] {
                let tree = Json::parse(body)
                    .map_err(|e| format!("invalid JSON body: {e}"))
                    .and_then(|j| DeploySpec::from_json(&j, path_name));
                let fast = DeploySpec::from_body(body, path_name);
                assert_eq!(fast, tree, "diverged on {body} (path_name {path_name:?})");
            }
        }
    }

    #[test]
    fn swap_fields_matches_the_tree_parser() {
        // (body, expected) — expected None means a 400 on both paths
        let cases: &[(&str, Option<(AgentKind, u64)>)] = &[
            (r#"{"agent":"ipa"}"#, Some((AgentKind::Ipa, 42))),
            (r#"{"agent":"greedy","seed":7}"#, Some((AgentKind::Greedy, 7))),
            // escaped agent name → bails to the tree path, which decodes it
            (r#"{"agent":"ip\u0061"}"#, Some((AgentKind::Ipa, 42))),
            (r#"{"agent":"nope"}"#, None),
            (r#"{"seed":7}"#, None),
            (r#"{"agent":5}"#, None),
            (r#"{"agent":"ipa","seed":1.5}"#, Some((AgentKind::Ipa, 42))),
            (r#"{"agent":"ipa""#, None),
        ];
        for (body, expected) in cases {
            match swap_fields(body) {
                Ok(got) => assert_eq!(Some(got), *expected, "{body}"),
                Err(resp) => {
                    assert!(expected.is_none(), "{body} unexpectedly rejected: {}", resp.body);
                    assert_eq!(resp.status, 400, "{body}");
                }
            }
        }
    }

    #[test]
    fn deploy_spec_rejects_bad_values() {
        for body in [
            r#"{"pipeline":"P1"}"#,
            r#"{"name":"a b","pipeline":"P1"}"#,
            r#"{"name":"a","pipeline":"P1","workload":"nope"}"#,
            r#"{"name":"a","pipeline":"P1","agent":"nope"}"#,
            r#"{"name":"a","pipeline":"P1","adapt_interval_secs":0}"#,
            r#"{"name":"a","pipeline":"P1","config":[{"batch":3}]}"#,
            r#"{"name":"a","pipeline":"P1","config":{}}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert!(DeploySpec::from_json(&j, None).is_err(), "{body}");
        }
    }
}
