//! Serving layer: the leader process's HTTP face. Classic observability —
//! Prometheus-format `/metrics`, JSON `/state`, `/series`, `/healthz` —
//! mirroring the paper's Prometheus/Grafana monitoring story, plus the
//! versioned v1 control-plane API (api.rs) backed by the leader loop
//! (leader.rs). The decision loop owns all sim state on one thread — the
//! sharded tick's worker pool (DESIGN.md §15) is internal to
//! `MultiEnv::tick` — so HTTP workers reach it only through `ControlMsg`
//! channels and the shared `ControlPlane` state.

pub mod api;
pub mod http;
pub mod leader;

use std::sync::{Arc, Mutex};

pub use api::{
    task_config_json, v1_router, ApiError, ControlMsg, ControlReply, ControlRequest, DeploySpec,
};
pub use http::{
    http_delete, http_get, http_post, http_put, http_request, HttpClient, HttpServer, Request,
    Response, Router, MAX_BODY_BYTES,
};
pub use leader::{status_json, Leader, TenantFactory};

use crate::telemetry::{MetricsRegistry, TimeSeriesStore};
use crate::util::json::Json;

/// Shared state between the coordinator loop and the HTTP server threads.
pub struct ControlPlane {
    pub metrics: Arc<MetricsRegistry>,
    pub series: Arc<TimeSeriesStore>,
    /// pre-rendered /state JSON; a String (not a `Json` tree) so the
    /// leader's per-tick publish reuses the buffer capacity (DESIGN.md §12)
    state: Mutex<String>,
}

impl Default for ControlPlane {
    fn default() -> Self {
        Self::new()
    }
}

impl ControlPlane {
    pub fn new() -> Self {
        Self {
            metrics: Arc::new(MetricsRegistry::new()),
            series: Arc::new(TimeSeriesStore::new(4096)),
            state: Mutex::new(String::from("{}")),
        }
    }

    /// Publish the coordinator's current view (shown at `/state`).
    pub fn publish_state(&self, state: Json) {
        let mut s = self.state.lock().unwrap();
        s.clear();
        state.write_compact_into(&mut s);
    }

    /// Publish a pre-rendered JSON snapshot, reusing the held buffer's
    /// capacity — the leader's per-tick hot path (DESIGN.md §12).
    pub fn publish_state_str(&self, state: &str) {
        let mut s = self.state.lock().unwrap();
        s.clear();
        s.push_str(state);
    }

    pub fn state_json(&self) -> String {
        self.state.lock().unwrap().clone()
    }

    /// The classic observability routes (/metrics /state /series /healthz);
    /// `v1_router` layers the control-plane API on top of this.
    pub fn base_router(self: &Arc<Self>) -> Router {
        let mut router = Router::new();
        let cp = self.clone();
        router.get("/metrics", move |_| Response::ok(cp.metrics.expose()));
        let cp = self.clone();
        router.get("/state", move |_| Response::json(cp.state_json()));
        router.get("/healthz", |_| Response::ok("ok\n"));
        let cp = self.clone();
        router.get("/series", move |req| {
            // /series?name=<series>&n=<count>
            let mut name = "load";
            let mut n = 120usize;
            for kv in req.query.split('&') {
                if let Some((k, v)) = kv.split_once('=') {
                    match k {
                        "name" => name = v,
                        "n" => n = v.parse().unwrap_or(120),
                        _ => {}
                    }
                }
            }
            let w = cp.series.window(name, n);
            Response::json(
                Json::obj()
                    .set("name", name)
                    .set("values", Json::Arr(w.iter().map(|x| Json::Num(*x)).collect()))
                    .to_string(),
            )
        });
        router
    }

    /// Build the observability router and start serving.
    pub fn serve(self: &Arc<Self>, addr: &str) -> std::io::Result<HttpServer> {
        HttpServer::start(addr, self.base_router(), 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_plane_endpoints() {
        let cp = Arc::new(ControlPlane::new());
        cp.metrics.set_gauge("qos", &[], 1.25);
        cp.series.record("load", 42.0);
        cp.publish_state(Json::obj().set("agent", "opd").set("t", 10.0));
        let server = cp.serve("127.0.0.1:0").unwrap();
        let addr = server.addr;

        let (code, body) = http_get(&addr, "/healthz").unwrap();
        assert_eq!((code, body.as_str()), (200, "ok\n"));

        let (code, body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("qos 1.25"));

        let (code, body) = http_get(&addr, "/state").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("\"agent\""));

        let (code, body) = http_get(&addr, "/series?name=load&n=5").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("42"));
        server.shutdown();
    }
}
