//! The leader loop: owns the multi-pipeline environment and answers
//! control-plane commands from the HTTP face over a channel. The sim/agent
//! state has exactly one writer — this loop — so the HTTP workers only ever
//! talk to the simulation through `ControlMsg`s; the loop interleaves
//! command handling with 1 s sim ticks. The tick itself may fan its decide
//! phase out over the sharded worker pool (`--tick-threads`, DESIGN.md
//! §15), but that pool is internal to `MultiEnv::tick` and hands control
//! back before any state is applied, so the one-writer discipline holds.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::agents::Agent;
use crate::cluster::{ClusterTopology, FaultPlan};
use crate::config::AgentKind;
use crate::pipeline::{catalog, QosWeights};
use crate::rl::online::{OnlineHandle, SharedPolicy};
use crate::serve::api::{task_config_json, ApiError, ControlMsg, ControlRequest, DeploySpec};
use crate::serve::ControlPlane;
use crate::sim::env::LoadSource;
use crate::sim::{MultiEnv, Tenant, TenantStatus};
use crate::util::json::{write_num, write_str, Json};
use crate::workload::predictor::{LoadPredictor, MovingMaxPredictor};
use crate::workload::WorkloadGen;

/// Builds agents/predictors for newly applied pipelines. Wired by the CLI;
/// the native constructor covers baseline agents without any PJRT wiring.
/// Products are `Send` so tenants can ride the sharded tick's worker pool
/// (DESIGN.md §15) — the `Arc<OpdRuntime>` handle keeps even the HLO-backed
/// agent and predictor `Send`.
pub struct TenantFactory {
    pub make_agent: Box<dyn Fn(AgentKind, u64) -> Result<Box<dyn Agent + Send>, String>>,
    pub make_predictor: Box<dyn Fn() -> Box<dyn LoadPredictor + Send>>,
}

impl TenantFactory {
    /// Baseline agents + moving-max predictor (no PJRT, no artifacts).
    pub fn native() -> Self {
        Self {
            make_agent: Box::new(|kind, seed| {
                crate::agents::baseline(kind, seed).ok_or_else(|| {
                    "the opd agent needs runtime wiring; boot the leader via `opd serve`"
                        .to_string()
                })
            }),
            make_predictor: Box::new(|| Box::new(MovingMaxPredictor::default())),
        }
    }
}

/// Per-pipeline gauges and series are emitted only up to this fleet size.
/// Past it the label cardinality (3 gauges + 4 series per tenant) would
/// swamp both the scrape payload and the per-tick publish cost, so large
/// fleets keep the aggregate signals only (DESIGN.md §12).
pub const PER_TENANT_TELEMETRY_MAX: usize = 256;

/// JSON view of one tenant status (shared by /v1 responses and /state).
pub fn status_json(s: &TenantStatus) -> Json {
    Json::obj()
        .set("name", s.name.as_str())
        .set("pipeline", s.pipeline.as_str())
        .set("agent", s.agent.as_str())
        .set("generation", s.generation as i64)
        .set("adapt_interval_secs", s.adapt_interval_secs)
        .set("load_now", s.load_now)
        .set("cores", s.cores)
        .set("avg_qos", s.avg_qos)
        .set("avg_cost", s.avg_cost)
        .set("last_qos", s.last_qos)
        .set("last_cost", s.last_cost)
        .set("load_pred", s.load_pred)
        .set("decisions", s.decisions)
        .set("clamped", s.clamped)
        .set("restarts", s.restarts)
        .set("last_decision_secs", s.last_decision_secs)
        .set("health", s.health.as_str())
        .set("degraded_secs", s.degraded_secs)
        .set("config", Json::Arr(s.config.iter().map(task_config_json).collect()))
        .set(
            "ready",
            Json::Arr(s.ready.iter().map(|r| Json::Num(*r as f64)).collect()),
        )
}

/// Streamed equivalent of [`status_json`] — identical field set and number
/// formatting — for the per-tick /state hot path.
fn write_status(buf: &mut String, s: &TenantStatus) {
    buf.push_str("{\"name\":");
    write_str(buf, &s.name);
    buf.push_str(",\"pipeline\":");
    write_str(buf, &s.pipeline);
    buf.push_str(",\"agent\":");
    write_str(buf, &s.agent);
    buf.push_str(",\"generation\":");
    write_num(buf, s.generation as f64);
    buf.push_str(",\"adapt_interval_secs\":");
    write_num(buf, s.adapt_interval_secs as f64);
    buf.push_str(",\"load_now\":");
    write_num(buf, s.load_now);
    buf.push_str(",\"cores\":");
    write_num(buf, s.cores);
    buf.push_str(",\"avg_qos\":");
    write_num(buf, s.avg_qos);
    buf.push_str(",\"avg_cost\":");
    write_num(buf, s.avg_cost);
    buf.push_str(",\"last_qos\":");
    write_num(buf, s.last_qos);
    buf.push_str(",\"last_cost\":");
    write_num(buf, s.last_cost);
    buf.push_str(",\"load_pred\":");
    write_num(buf, s.load_pred);
    buf.push_str(",\"decisions\":");
    write_num(buf, s.decisions as f64);
    buf.push_str(",\"clamped\":");
    write_num(buf, s.clamped as f64);
    buf.push_str(",\"restarts\":");
    write_num(buf, s.restarts as f64);
    buf.push_str(",\"last_decision_secs\":");
    write_num(buf, s.last_decision_secs);
    buf.push_str(",\"health\":");
    write_str(buf, s.health.as_str());
    buf.push_str(",\"degraded_secs\":");
    write_num(buf, s.degraded_secs);
    buf.push_str(",\"config\":[");
    for (i, c) in s.config.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        buf.push_str("{\"variant\":");
        write_num(buf, c.variant as f64);
        buf.push_str(",\"replicas\":");
        write_num(buf, c.replicas as f64);
        buf.push_str(",\"batch\":");
        write_num(buf, c.batch() as f64);
        buf.push('}');
    }
    buf.push_str("],\"ready\":[");
    for (i, r) in s.ready.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        write_num(buf, *r as f64);
    }
    buf.push_str("]}");
}

/// The leader process state.
pub struct Leader {
    pub env: MultiEnv,
    cp: Arc<ControlPlane>,
    rx: Receiver<ControlMsg>,
    factory: TenantFactory,
    /// QoS weights handed to every new tenant
    pub weights: QosWeights,
    /// pace ticks to wall-clock seconds
    pub realtime: bool,
    /// stop once simulated time reaches this (None → run until shutdown)
    pub max_secs: Option<f64>,
    /// per-tenant decision counts already published (for counter deltas),
    /// tagged with the last publish epoch that saw the tenant — stale rows
    /// are swept only when tenants actually disappeared, replacing the old
    /// per-tick O(tenants²) retain scan
    published_decisions: std::collections::BTreeMap<String, (u64, usize)>,
    publish_epoch: u64,
    /// batched-decision totals already published (for counter deltas)
    published_batched: (usize, usize),
    /// batched-prediction totals already published (for counter deltas)
    published_batched_pred: (usize, usize),
    /// failure-path totals already published (node failures, evacuations,
    /// repairs, tenant kills — counter deltas, DESIGN.md §13)
    published_failures: (usize, usize, usize, usize),
    /// online learning (DESIGN.md §11): the trainer's shared policy cell,
    /// polled for update/transition counter deltas each publish tick
    online: Option<Arc<SharedPolicy>>,
    /// (updates, transitions) totals already published (for counter deltas)
    published_online: (u64, u64),
    /// update-latency drain scratch, reused every publish tick
    latency_scratch: Vec<f64>,
    /// publish-tick scratch, reused every second (telemetry hot loop)
    status_scratch: Vec<TenantStatus>,
    key_buf: String,
    /// reused /state render buffer — the snapshot is streamed as compact
    /// JSON instead of built as a `Json` tree (DESIGN.md §12)
    state_buf: String,
}

impl Leader {
    /// Create a leader plus the command-channel sender the HTTP router needs.
    pub fn new(
        cp: Arc<ControlPlane>,
        topo: ClusterTopology,
        startup_secs: f64,
        factory: TenantFactory,
    ) -> (Leader, Sender<ControlMsg>) {
        let (tx, rx) = channel();
        (
            Leader {
                env: MultiEnv::new(topo, startup_secs),
                cp,
                rx,
                factory,
                weights: QosWeights::default(),
                realtime: false,
                max_secs: None,
                published_decisions: std::collections::BTreeMap::new(),
                publish_epoch: 0,
                published_batched: (0, 0),
                published_batched_pred: (0, 0),
                published_failures: (0, 0, 0, 0),
                online: None,
                published_online: (0, 0),
                latency_scratch: Vec::new(),
                status_scratch: Vec::new(),
                key_buf: String::new(),
                state_buf: String::new(),
            },
            tx,
        )
    }

    /// Attach a running online trainer (`opd serve --learn` — DESIGN.md
    /// §11): the env streams transitions to it and adopts its published
    /// policy generations at tick boundaries; `publish` exports the
    /// trainer's counters. Call `env.take_online()` before
    /// `OnlineHandle::finish()` so the trainer sees the channel close.
    pub fn enable_online(&mut self, handle: &OnlineHandle) {
        self.env.set_online(handle.hook());
        self.online = Some(handle.shared.clone());
    }

    /// Deploy a pipeline directly (the CLI bootstrap path, before `run`).
    pub fn deploy(&mut self, spec: &DeploySpec) -> Result<Json, ApiError> {
        self.apply_spec(spec, false).map(|(_, j)| j)
    }

    fn apply_spec(
        &mut self,
        spec: &DeploySpec,
        create_only: bool,
    ) -> Result<(u16, Json), ApiError> {
        let existed = self.env.contains(&spec.name);
        if create_only && existed {
            return Err(ApiError::conflict(format!(
                "pipeline '{}' already exists (PUT /v1/pipelines/{} to update)",
                spec.name, spec.name
            )));
        }
        let np = catalog::by_name(&spec.pipeline).ok_or_else(|| {
            ApiError::bad_request(format!(
                "unknown pipeline '{}' (available: {})",
                spec.pipeline,
                catalog::available().join(", ")
            ))
        })?;
        if np.spec.n_tasks() > crate::nn::spec::MAX_TASKS {
            return Err(ApiError::bad_request(format!(
                "pipeline '{}' has {} stages; the NN interface supports up to {}",
                spec.pipeline,
                np.spec.n_tasks(),
                crate::nn::spec::MAX_TASKS
            )));
        }
        let agent =
            (self.factory.make_agent)(spec.agent, spec.seed).map_err(ApiError::internal)?;
        let predictor = (self.factory.make_predictor)();
        let tenant = Tenant::new(
            spec.name.clone(),
            np.spec,
            agent,
            self.weights,
            LoadSource::Gen(WorkloadGen::new(spec.workload, spec.seed)),
            predictor,
            spec.adapt_interval_secs,
        );
        let out = self.env.deploy(tenant, spec.initial.clone()).map_err(ApiError::bad_request)?;
        let status = self.env.status(&spec.name).expect("just deployed");
        let body = status_json(&status)
            .set("clamped_on_apply", out.clamped)
            .set("workload", spec.workload.name());
        Ok((if existed { 200 } else { 201 }, body))
    }

    fn cluster_json(&self) -> Json {
        let topo = &self.env.store.topo;
        Json::obj()
            .set("now", self.env.now)
            .set("capacity", topo.capacity())
            .set("used", topo.used())
            .set("free", topo.free())
            .set("policy_generation", self.env.policy_generation as i64)
            .set(
                "nodes",
                Json::Arr(
                    topo.nodes
                        .iter()
                        .map(|n| {
                            Json::obj()
                                .set("name", n.name.as_str())
                                .set("cores_total", n.cores_total)
                                .set("cores_used", n.cores_used)
                                .set("up", n.up)
                        })
                        .collect(),
                ),
            )
            .set(
                "pipelines",
                Json::Arr(
                    self.env
                        .statuses()
                        .iter()
                        .map(|s| {
                            Json::obj()
                                .set("name", s.name.as_str())
                                .set("cores", s.cores)
                                .set("generation", s.generation as i64)
                                .set("agent", s.agent.as_str())
                        })
                        .collect(),
                ),
            )
    }

    fn handle(&mut self, req: ControlRequest) -> Result<(u16, Json), ApiError> {
        match req {
            ControlRequest::ListPipelines => {
                let arr: Vec<Json> = self.env.statuses().iter().map(status_json).collect();
                Ok((
                    200,
                    Json::obj().set("now", self.env.now).set("pipelines", Json::Arr(arr)),
                ))
            }
            ControlRequest::GetPipeline(name) => self
                .env
                .status(&name)
                .map(|s| (200, status_json(&s)))
                .ok_or_else(|| ApiError::not_found(format!("no pipeline named '{name}'"))),
            ControlRequest::ApplyPipeline { spec, create_only } => {
                self.apply_spec(&spec, create_only)
            }
            ControlRequest::DeletePipeline(name) => {
                if self.env.remove(&name) {
                    self.evict_tenant_telemetry(&name);
                    Ok((200, Json::obj().set("deleted", name.as_str())))
                } else {
                    Err(ApiError::not_found(format!("no pipeline named '{name}'")))
                }
            }
            ControlRequest::SwapAgent { pipeline, agent, seed } => {
                if !self.env.contains(&pipeline) {
                    return Err(ApiError::not_found(format!("no pipeline named '{pipeline}'")));
                }
                let a = (self.factory.make_agent)(agent, seed).map_err(ApiError::internal)?;
                self.env.set_agent(&pipeline, a).map_err(ApiError::not_found)?;
                let s = self.env.status(&pipeline).expect("checked above");
                Ok((200, status_json(&s)))
            }
            ControlRequest::GetCluster => Ok((200, self.cluster_json())),
            ControlRequest::Chaos(spec) => {
                let n_nodes = self.env.store.topo.nodes.len();
                let plan =
                    FaultPlan::parse(&spec, n_nodes).map_err(ApiError::bad_request)?;
                let scheduled = self.env.schedule_plan(&plan, self.env.now);
                Ok((
                    200,
                    Json::obj()
                        .set("scheduled", scheduled)
                        .set("pending", self.env.pending_faults())
                        .set("at", self.env.now),
                ))
            }
            ControlRequest::Shutdown => Ok((200, Json::obj().set("shutdown", true))),
        }
    }

    /// Answer one command; returns true when the loop should stop.
    fn process(&mut self, msg: ControlMsg) -> bool {
        let shutdown = matches!(msg.req, ControlRequest::Shutdown);
        let reply = self.handle(msg.req);
        let _ = msg.reply.send(reply);
        shutdown
    }

    /// Drop a deleted tenant's per-pipeline gauges, series and interned
    /// publish rows. Without this the leader's metric-key maps only ever
    /// grow under deploy/delete churn — the labels of dead tenants pin
    /// memory and bloat every `/metrics` scrape forever (DESIGN.md §15).
    fn evict_tenant_telemetry(&mut self, name: &str) {
        use std::fmt::Write as _;
        let m = &self.cp.metrics;
        for gauge in ["opd_qos", "opd_cost_cores", "opd_load"] {
            m.remove_series(gauge, &[("pipeline", name)]);
        }
        for prefix in ["load", "load_pred", "qos", "cost", "degraded"] {
            self.key_buf.clear();
            let _ = write!(self.key_buf, "{prefix}:{name}");
            self.cp.series.remove(&self.key_buf);
        }
        self.published_decisions.remove(name);
    }

    /// Publish the tick's metrics/state to the observability endpoints.
    /// Runs every simulated second, so the per-tenant series keys and the
    /// status snapshot go through reused buffers instead of fresh
    /// allocations (the telemetry hot-loop cleanup — DESIGN.md §9).
    fn publish(&mut self) {
        use std::fmt::Write as _;
        self.env.statuses_into(&mut self.status_scratch);
        let statuses = std::mem::take(&mut self.status_scratch);
        let m = &self.cp.metrics;
        self.publish_epoch += 1;
        let epoch = self.publish_epoch;
        let per_tenant = statuses.len() <= PER_TENANT_TELEMETRY_MAX;
        let mut total_load = 0.0;
        let mut total_pred = 0.0;
        let mut qos_sum = 0.0;
        let mut cost_sum = 0.0;
        let mut record_keyed = |key_buf: &mut String, prefix: &str, name: &str, v: f64| {
            key_buf.clear();
            let _ = write!(key_buf, "{prefix}:{name}");
            self.cp.series.record(key_buf, v);
        };
        for s in &statuses {
            if per_tenant {
                m.set_gauge("opd_qos", &[("pipeline", s.name.as_str())], s.last_qos);
                m.set_gauge("opd_cost_cores", &[("pipeline", s.name.as_str())], s.last_cost);
                m.set_gauge("opd_load", &[("pipeline", s.name.as_str())], s.load_now);
                record_keyed(&mut self.key_buf, "load", &s.name, s.load_now);
                record_keyed(&mut self.key_buf, "load_pred", &s.name, s.load_pred);
                record_keyed(&mut self.key_buf, "qos", &s.name, s.last_qos);
                record_keyed(&mut self.key_buf, "cost", &s.name, s.last_cost);
                record_keyed(&mut self.key_buf, "degraded", &s.name, s.degraded_secs);
            }
            total_load += s.load_now;
            total_pred += s.load_pred;
            qos_sum += s.last_qos;
            cost_sum += s.last_cost;
            // decision counter/timing: publish only the delta since the last
            // tick (a replaced tenant resets its count — just resync then)
            match self.published_decisions.get_mut(&s.name) {
                Some(e) => {
                    if s.decisions > e.1 {
                        m.inc("opd_decisions_total", &[], (s.decisions - e.1) as f64);
                        m.observe("opd_decision_seconds", &[], s.last_decision_secs);
                    }
                    *e = (epoch, s.decisions);
                }
                None => {
                    if s.decisions > 0 {
                        m.inc("opd_decisions_total", &[], s.decisions as f64);
                        m.observe("opd_decision_seconds", &[], s.last_decision_secs);
                    }
                    self.published_decisions.insert(s.name.clone(), (epoch, s.decisions));
                }
            }
        }
        // sweep rows whose tenant disappeared — only when one actually did,
        // so the steady-state tick skips the scan entirely
        if self.published_decisions.len() > statuses.len() {
            self.published_decisions.retain(|_, (ep, _)| *ep == epoch);
        }
        let n = statuses.len().max(1) as f64;
        self.cp.series.record("load", total_load);
        self.cp.series.record("load_pred", total_pred);
        self.cp.series.record("qos", qos_sum / n);
        self.cp.series.record("cost", cost_sum);
        m.set_gauge("opd_pipelines", &[], statuses.len() as f64);
        m.set_gauge("opd_cluster_used_cores", &[], self.env.store.topo.used());
        m.set_gauge("opd_cluster_free_cores", &[], self.env.store.topo.free());
        // failure path (DESIGN.md §13): chaos/fault counters + fleet health
        m.set_gauge("opd_nodes_up", &[], self.env.store.topo.n_up() as f64);
        m.set_gauge("opd_degraded_tenants", &[], self.env.degraded_count() as f64);
        let (seen_nf, seen_ev, seen_rp, seen_tk) = self.published_failures;
        if self.env.node_failures > seen_nf {
            m.inc("opd_node_failures_total", &[], (self.env.node_failures - seen_nf) as f64);
        }
        if self.env.evacuations > seen_ev {
            m.inc("opd_evacuations_total", &[], (self.env.evacuations - seen_ev) as f64);
        }
        if self.env.repairs > seen_rp {
            m.inc("opd_repairs_total", &[], (self.env.repairs - seen_rp) as f64);
        }
        if self.env.tenant_kills > seen_tk {
            m.inc("opd_tenant_kills_total", &[], (self.env.tenant_kills - seen_tk) as f64);
        }
        self.published_failures = (
            self.env.node_failures,
            self.env.evacuations,
            self.env.repairs,
            self.env.tenant_kills,
        );
        // batched decision path (DESIGN.md §7): how many decisions were
        // evaluated through a shared batched forward, and in how many groups
        let (seen_dec, seen_grp) = self.published_batched;
        if self.env.batched_decisions > seen_dec {
            m.inc(
                "opd_batched_decisions_total",
                &[],
                (self.env.batched_decisions - seen_dec) as f64,
            );
        }
        if self.env.batched_groups > seen_grp {
            m.inc(
                "opd_batched_forwards_total",
                &[],
                (self.env.batched_groups - seen_grp) as f64,
            );
        }
        self.published_batched = (self.env.batched_decisions, self.env.batched_groups);
        // batched predictor path (DESIGN.md §9): load predictions served by
        // a shared batched LSTM pass, and how many passes ran
        let (seen_pred, seen_pred_grp) = self.published_batched_pred;
        if self.env.batched_predictions > seen_pred {
            m.inc(
                "opd_batched_predictions_total",
                &[],
                (self.env.batched_predictions - seen_pred) as f64,
            );
        }
        if self.env.batched_predictor_groups > seen_pred_grp {
            m.inc(
                "opd_batched_predictor_passes_total",
                &[],
                (self.env.batched_predictor_groups - seen_pred_grp) as f64,
            );
        }
        self.published_batched_pred =
            (self.env.batched_predictions, self.env.batched_predictor_groups);
        // online learning (DESIGN.md §11): trainer progress + fleet adoption
        if let Some(shared) = &self.online {
            let (seen_upd, seen_tr) = self.published_online;
            let updates = shared.updates();
            let transitions = self.env.online_transitions as u64;
            if updates > seen_upd {
                m.inc("opd_online_updates_total", &[], (updates - seen_upd) as f64);
            }
            if transitions > seen_tr {
                m.inc("opd_online_transitions_total", &[], (transitions - seen_tr) as f64);
            }
            self.published_online = (updates, transitions);
            m.set_gauge("opd_policy_generation", &[], self.env.policy_generation as f64);
            shared.drain_latencies(&mut self.latency_scratch);
            for &secs in &self.latency_scratch {
                m.observe("opd_online_update_seconds", &[], secs);
                self.cp.series.record("online_update_secs", secs);
            }
        }
        self.write_state(&statuses);
        // hand the snapshot buffer back for the next tick
        self.status_scratch = statuses;
    }

    /// Render the /state snapshot as compact JSON into the reused buffer
    /// and publish it by reference — a `Json` tree allocates per node,
    /// which at thousands of tenants dominated the tick (DESIGN.md §12).
    /// Shape and values mirror `status_json`/`cluster_json` exactly.
    fn write_state(&mut self, statuses: &[TenantStatus]) {
        let buf = &mut self.state_buf;
        buf.clear();
        buf.push_str("{\"t\":");
        write_num(buf, self.env.now);
        buf.push_str(",\"pipelines\":[");
        for (i, s) in statuses.iter().enumerate() {
            if i > 0 {
                buf.push(',');
            }
            write_status(buf, s);
        }
        buf.push_str("],\"cluster\":{\"now\":");
        let topo = &self.env.store.topo;
        write_num(buf, self.env.now);
        buf.push_str(",\"capacity\":");
        write_num(buf, topo.capacity());
        buf.push_str(",\"used\":");
        write_num(buf, topo.used());
        buf.push_str(",\"free\":");
        write_num(buf, topo.free());
        buf.push_str(",\"policy_generation\":");
        write_num(buf, self.env.policy_generation as f64);
        buf.push_str(",\"nodes\":[");
        for (i, node) in topo.nodes.iter().enumerate() {
            if i > 0 {
                buf.push(',');
            }
            buf.push_str("{\"name\":");
            write_str(buf, &node.name);
            buf.push_str(",\"cores_total\":");
            write_num(buf, node.cores_total);
            buf.push_str(",\"cores_used\":");
            write_num(buf, node.cores_used);
            buf.push_str(",\"up\":");
            buf.push_str(if node.up { "true" } else { "false" });
            buf.push('}');
        }
        buf.push_str("],\"pipelines\":[");
        for (i, s) in statuses.iter().enumerate() {
            if i > 0 {
                buf.push(',');
            }
            buf.push_str("{\"name\":");
            write_str(buf, &s.name);
            buf.push_str(",\"cores\":");
            write_num(buf, s.cores);
            buf.push_str(",\"generation\":");
            write_num(buf, s.generation as f64);
            buf.push_str(",\"agent\":");
            write_str(buf, &s.agent);
            buf.push('}');
        }
        buf.push_str("]}}");
        self.cp.publish_state_str(buf);
    }

    /// Main loop. Returns when a shutdown command arrives, every command
    /// sender is gone, or simulated time reaches `max_secs`. With no
    /// pipelines deployed the clock does not advance — the leader idles,
    /// waiting for `POST /v1/pipelines`.
    pub fn run(&mut self) {
        loop {
            // drain pending control commands
            loop {
                match self.rx.try_recv() {
                    Ok(msg) => {
                        if self.process(msg) {
                            return;
                        }
                    }
                    Err(_) => break,
                }
            }
            if self.env.n_tenants() == 0 {
                // idle: block briefly for a command instead of spinning
                match self.rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(msg) => {
                        if self.process(msg) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return,
                }
                continue;
            }
            let t0 = Instant::now();
            self.env.tick();
            self.publish();
            if let Some(max) = self.max_secs {
                if self.env.now + 1e-9 >= max {
                    return;
                }
            }
            if self.realtime {
                // sleep out the remainder of the second, staying responsive
                loop {
                    let elapsed = t0.elapsed();
                    if elapsed >= Duration::from_secs(1) {
                        break;
                    }
                    match self.rx.recv_timeout(Duration::from_secs(1) - elapsed) {
                        Ok(msg) => {
                            if self.process(msg) {
                                return;
                            }
                        }
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadKind;

    fn spec(name: &str, pipeline: &str, agent: AgentKind) -> DeploySpec {
        DeploySpec {
            name: name.into(),
            pipeline: pipeline.into(),
            workload: WorkloadKind::SteadyLow,
            agent,
            adapt_interval_secs: 10,
            seed: 1,
            initial: None,
        }
    }

    fn leader() -> (Leader, Sender<ControlMsg>) {
        Leader::new(
            Arc::new(ControlPlane::new()),
            ClusterTopology::paper_testbed(),
            1.0,
            TenantFactory::native(),
        )
    }

    #[test]
    fn handle_covers_crud_and_errors() {
        let (mut l, _tx) = leader();
        // create
        let (code, body) = l
            .handle(ControlRequest::ApplyPipeline {
                spec: spec("a", "P1", AgentKind::Greedy),
                create_only: true,
            })
            .unwrap();
        assert_eq!(code, 201);
        assert_eq!(body.req_str("agent").unwrap(), "greedy");
        // duplicate POST → 409
        let err = l
            .handle(ControlRequest::ApplyPipeline {
                spec: spec("a", "P1", AgentKind::Greedy),
                create_only: true,
            })
            .unwrap_err();
        assert_eq!(err.status, 409);
        // PUT updates in place → 200
        let (code, _) = l
            .handle(ControlRequest::ApplyPipeline {
                spec: spec("a", "P2", AgentKind::Random),
                create_only: false,
            })
            .unwrap();
        assert_eq!(code, 200);
        // unknown catalog name → 400
        let err = l
            .handle(ControlRequest::ApplyPipeline {
                spec: spec("b", "nope", AgentKind::Greedy),
                create_only: true,
            })
            .unwrap_err();
        assert_eq!(err.status, 400);
        // get / list / cluster
        let (code, body) = l.handle(ControlRequest::GetPipeline("a".into())).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.req_str("pipeline").unwrap(), "P2");
        let err = l.handle(ControlRequest::GetPipeline("zz".into())).unwrap_err();
        assert_eq!(err.status, 404);
        let (_, body) = l.handle(ControlRequest::ListPipelines).unwrap();
        assert_eq!(body.get("pipelines").unwrap().as_arr().unwrap().len(), 1);
        let (_, body) = l.handle(ControlRequest::GetCluster).unwrap();
        assert!(body.req_f64("capacity").unwrap() > 0.0);
        // swap agent: bumps the deployment generation (API-visible)
        let (_, body) = l.handle(ControlRequest::GetPipeline("a".into())).unwrap();
        let gen_before = body.req_f64("generation").unwrap() as u64;
        let (code, body) = l
            .handle(ControlRequest::SwapAgent {
                pipeline: "a".into(),
                agent: AgentKind::Ipa,
                seed: 2,
            })
            .unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.req_str("agent").unwrap(), "ipa");
        assert_eq!(body.req_f64("generation").unwrap() as u64, gen_before + 1);
        let (_, body) = l.handle(ControlRequest::GetPipeline("a".into())).unwrap();
        assert_eq!(body.req_f64("generation").unwrap() as u64, gen_before + 1);
        // delete
        let (code, _) = l.handle(ControlRequest::DeletePipeline("a".into())).unwrap();
        assert_eq!(code, 200);
        let err = l.handle(ControlRequest::DeletePipeline("a".into())).unwrap_err();
        assert_eq!(err.status, 404);
    }

    #[test]
    fn streamed_state_matches_the_tree_renderer() {
        let (mut l, _tx) = leader();
        l.deploy(&spec("a", "P1", AgentKind::Greedy)).unwrap();
        l.deploy(&spec("b", "P2", AgentKind::Random)).unwrap();
        for _ in 0..12 {
            l.env.tick();
        }
        l.publish();
        let state = l.cp.state_json();
        let j = Json::parse(&state).expect("streamed /state is valid JSON");
        assert_eq!(j.req_f64("t").unwrap(), l.env.now);
        let pipes = j.get("pipelines").unwrap().as_arr().unwrap();
        assert_eq!(pipes.len(), 2);
        // field-identical to the status_json tree view
        let tree = status_json(&l.env.status("a").unwrap());
        let streamed = pipes.iter().find(|p| p.req_str("name").unwrap() == "a").unwrap();
        assert_eq!(streamed.to_string(), tree.to_string());
        let cluster = j.get("cluster").unwrap();
        assert_eq!(cluster.req_f64("capacity").unwrap(), 30.0);
        assert_eq!(cluster.get("pipelines").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(cluster.get("nodes").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn per_tenant_telemetry_gates_above_the_cardinality_cap() {
        let (mut l, _tx) = Leader::new(
            Arc::new(ControlPlane::new()),
            ClusterTopology::uniform(64, 64.0),
            1.0,
            TenantFactory::native(),
        );
        for i in 0..=PER_TENANT_TELEMETRY_MAX {
            l.deploy(&spec(&format!("t{i:04}"), "P1", AgentKind::Greedy)).unwrap();
        }
        l.env.tick();
        l.publish();
        let text = l.cp.metrics.expose();
        assert!(!text.contains("opd_qos{"), "per-tenant gauges gated past the cap");
        assert!(text.contains("opd_pipelines"), "aggregate signals stay");
        // shrink below the cap: per-tenant signals resume
        assert!(l.env.remove("t0000"));
        l.env.tick();
        l.publish();
        let text = l.cp.metrics.expose();
        assert!(text.contains("opd_qos{"), "per-tenant gauges resume under the cap");
    }

    #[test]
    fn delete_evicts_tenant_telemetry_under_churn() {
        let (mut l, _tx) = leader();
        l.deploy(&spec("keep", "P1", AgentKind::Greedy)).unwrap();
        for round in 0..100 {
            let name = format!("churn{round:03}");
            l.handle(ControlRequest::ApplyPipeline {
                spec: spec(&name, "P2", AgentKind::Random),
                create_only: true,
            })
            .unwrap();
            for _ in 0..3 {
                l.env.tick();
                l.publish();
            }
            l.handle(ControlRequest::DeletePipeline(name)).unwrap();
        }
        // the interned publish rows and the per-pipeline gauges/series must
        // not retain the 100 dead tenants
        assert_eq!(l.published_decisions.len(), 1, "only the survivor remains");
        assert!(l.published_decisions.contains_key("keep"));
        let text = l.cp.metrics.expose();
        assert!(!text.contains("churn0"), "dead-tenant gauges evicted:\n{text}");
        assert!(text.contains("opd_qos{pipeline=\"keep\"}"), "survivor gauges stay");
        let mut names = Vec::new();
        l.cp.series.for_each_name(|n| names.push(n.to_string()));
        assert!(
            names.iter().all(|n| !n.contains("churn")),
            "dead-tenant series evicted: {names:?}"
        );
        assert!(names.iter().any(|n| n == "qos:keep"), "survivor series stay");
    }

    #[test]
    fn chaos_request_schedules_and_the_fleet_self_heals() {
        let (mut l, _tx) = leader();
        l.deploy(&spec("a", "P1", AgentKind::Greedy)).unwrap();
        // malformed plans are a 400, not a leader crash
        let err = l.handle(ControlRequest::Chaos("explode@1=0".into())).unwrap_err();
        assert_eq!(err.status, 400);
        let err = l.handle(ControlRequest::Chaos("crash@1=9".into())).unwrap_err();
        assert_eq!(err.status, 400, "node index validated against the topology");
        let (code, body) =
            l.handle(ControlRequest::Chaos("crash@0=0,recover@3=0".into())).unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.req_f64("scheduled").unwrap() as usize, 2);
        for _ in 0..6 {
            l.env.tick();
        }
        l.publish();
        assert_eq!(l.env.node_failures, 1);
        assert_eq!(l.env.degraded_count(), 0, "spare capacity healed the fleet");
        let text = l.cp.metrics.expose();
        assert!(text.contains("opd_node_failures_total 1"), "{text}");
        assert!(text.contains("opd_evacuations_total"));
        assert!(text.contains("opd_repairs_total 1"));
        assert!(text.contains("opd_degraded_tenants 0"));
        assert!(text.contains("opd_nodes_up 3"));
        // health travels through the /v1 status and cluster views
        let (_, body) = l.handle(ControlRequest::GetPipeline("a".into())).unwrap();
        assert_eq!(body.req_str("health").unwrap(), "healthy");
        let (_, body) = l.handle(ControlRequest::GetCluster).unwrap();
        let nodes = body.get("nodes").unwrap().as_arr().unwrap();
        assert!(nodes.iter().all(|n| n.get("up").is_some()));
    }

    #[test]
    fn run_stops_on_shutdown_command() {
        let (mut l, tx) = leader();
        let (rtx, rrx) = channel();
        tx.send(ControlMsg { req: ControlRequest::Shutdown, reply: rtx }).unwrap();
        l.run(); // must return promptly without any tenants
        assert!(rrx.recv().unwrap().is_ok());
    }

    #[test]
    fn run_stops_at_max_secs() {
        let (mut l, _tx) = leader();
        l.max_secs = Some(30.0);
        l.deploy(&spec("a", "P1", AgentKind::Greedy)).unwrap();
        l.run();
        assert!(l.env.now + 1e-9 >= 30.0);
        assert!(l.env.status("a").unwrap().decisions >= 2);
    }
}
