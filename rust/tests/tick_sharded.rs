//! Sharded-tick invariance (DESIGN.md §15): `MultiEnv::tick` fans its
//! decide phase out over `tick_threads` workers, and the contract is that
//! the thread count is observationally invisible — per-tenant config
//! history, agent RNG stream positions, the store's usage index and every
//! batching/fault counter must be bitwise identical at any `--tick-threads`,
//! with a seeded chaos plan running (faults/repairs stay serial).

use opd::cluster::{ClusterTopology, FaultPlan};
use opd::pipeline::{catalog, QosWeights};
use opd::sim::{LoadSource, MultiEnv, Tenant};
use opd::workload::predictor::{LstmPredictor, MovingMaxPredictor};
use opd::workload::{WorkloadGen, WorkloadKind};

/// Deterministic policy parameter vector (shared by a fingerprint group).
fn shared_params(seed: u64) -> Vec<f32> {
    let mut rng = opd::util::prng::Pcg32::new(seed);
    (0..opd::nn::spec::POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
}

/// Deterministic LSTM predictor weights (shared by a predictor group).
fn shared_pred_weights(seed: u64) -> Vec<f32> {
    let mut rng = opd::util::prng::Pcg32::new(seed);
    (0..opd::nn::spec::PREDICTOR_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
}

/// A mixed fleet exercising every decide path the sharded tick has: OPD
/// natives in two shared-parameter groups (batched policy forwards + batched
/// LSTM predictions), greedy baselines on the sequential path, and varied
/// adapt intervals so due sets differ tick to tick.
fn fleet(n: usize) -> MultiEnv {
    let mut env = MultiEnv::new(ClusterTopology::uniform(64, 64.0), 1.0);
    let params = [shared_params(21), shared_params(22)];
    let pred_weights = shared_pred_weights(33);
    let pipelines = ["P1", "P2", "P3", "P4"];
    for i in 0..n {
        let name = format!("t{i:04}");
        let spec = catalog::by_name(pipelines[i % pipelines.len()]).unwrap().spec;
        let interval = [1, 2, 3, 5][i % 4];
        let kind = if i % 2 == 0 { WorkloadKind::Fluctuating } else { WorkloadKind::SteadyLow };
        let tenant = if i % 8 == 0 {
            let mut agent = opd::agents::OpdAgent::native(params[(i / 8) % 2].clone(), i as u64);
            agent.greedy = false; // sampling → the RNG stream position matters
            Tenant::new(
                name,
                spec,
                Box::new(agent),
                QosWeights::default(),
                LoadSource::Gen(WorkloadGen::new(kind, i as u64)),
                Box::new(LstmPredictor::native(pred_weights.clone())),
                interval,
            )
        } else {
            Tenant::new(
                name,
                spec,
                Box::new(opd::agents::GreedyAgent::new()),
                QosWeights::default(),
                LoadSource::Gen(WorkloadGen::new(kind, i as u64)),
                Box::new(MovingMaxPredictor::default()),
                interval,
            )
        };
        env.deploy(tenant, None).unwrap();
    }
    env.schedule_plan(&FaultPlan::seeded(5, 64, 18.0, 6.0), 0.0);
    env
}

/// Run `ticks` seconds at a given shard width and fingerprint every tick —
/// the full per-tick trajectory must match, not just the end state.
fn trace(n: usize, threads: usize, ticks: usize) -> Vec<u64> {
    let mut env = fleet(n);
    env.tick_threads = threads;
    (0..ticks)
        .map(|_| {
            env.tick();
            env.tick_fingerprint()
        })
        .collect()
}

#[test]
fn single_tenant_is_thread_invariant() {
    let base = trace(1, 1, 30);
    for threads in [2, 4, 8] {
        assert_eq!(trace(1, threads, 30), base, "{threads} threads diverged");
    }
}

#[test]
fn mid_fleet_is_thread_invariant() {
    let base = trace(64, 1, 24);
    for threads in [2, 4, 8] {
        assert_eq!(trace(64, threads, 24), base, "{threads} threads diverged");
    }
}

#[test]
fn large_fleet_is_thread_invariant() {
    let base = trace(300, 1, 16);
    for threads in [2, 4, 8] {
        assert_eq!(trace(300, threads, 16), base, "{threads} threads diverged");
    }
}

/// The batched paths actually engage under sharding (the invariance above
/// would be vacuous if every tenant fell back to the sequential path), and
/// the chaos plan actually fires.
#[test]
fn sharded_run_exercises_batched_paths_and_chaos() {
    let mut env = fleet(64);
    env.tick_threads = 4;
    for _ in 0..24 {
        env.tick();
    }
    assert!(env.batched_decisions > 0, "OPD groups should batch-decide");
    assert!(env.batched_predictions > 0, "LSTM groups should batch-predict");
    assert!(env.node_failures > 0, "the seeded plan should fire by t=18");
    assert_eq!(env.n_tenants(), 64, "node failures must not drop tenants");
}
