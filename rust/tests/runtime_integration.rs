//! Integration tests over the PJRT runtime + AOT artifacts: the HLO programs
//! must load, execute, and agree with the pure-rust mirrors.
//!
//! These need `make artifacts` to have run; they are skipped (with a notice)
//! when the artifacts are absent so `cargo test` stays usable pre-AOT.

use opd::nn::policy::{policy_fwd_scratch, predictor_fwd_native, PolicyScratch};
use opd::nn::spec::*;
use opd::runtime::OpdRuntime;
use opd::util::prng::Pcg32;

fn runtime() -> Option<OpdRuntime> {
    match OpdRuntime::load(None) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_matches_binary_constants() {
    let Some(rt) = runtime() else { return };
    rt.manifest.validate().unwrap();
    assert_eq!(rt.policy_init.len(), POLICY_PARAM_COUNT);
    assert_eq!(rt.predictor_weights.len(), PREDICTOR_PARAM_COUNT);
}

#[test]
fn policy_fwd_hlo_matches_native_mirror() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(7);
    let mut scratch = PolicyScratch::default();
    for trial in 0..5 {
        let state: Vec<f32> =
            (0..STATE_DIM).map(|_| (rng.normal() * 0.5) as f32).collect();
        let (hlo_logits, hlo_value) = rt.policy_forward(&rt.policy_init, &state).unwrap();
        let (nat_logits, nat_value) = policy_fwd_scratch(&rt.policy_init, &state, &mut scratch);
        assert_eq!(hlo_logits.len(), LOGITS_DIM);
        for (i, (a, b)) in hlo_logits.iter().zip(nat_logits).enumerate() {
            assert!(
                (a - b).abs() < 2e-3 + 1e-3 * b.abs(),
                "trial {trial} logit {i}: hlo {a} vs native {b}"
            );
        }
        assert!(
            (hlo_value - nat_value).abs() < 2e-3 + 1e-3 * nat_value.abs(),
            "value: {hlo_value} vs {nat_value}"
        );
    }
}

#[test]
fn predictor_hlo_matches_native_mirror() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg32::new(11);
    for _ in 0..3 {
        let window: Vec<f32> =
            (0..PRED_WINDOW).map(|_| rng.uniform_range(5.0, 180.0) as f32).collect();
        let hlo = rt.predict_load(&window).unwrap();
        let native = predictor_fwd_native(&rt.predictor_weights, &window);
        assert!(
            (hlo - native).abs() < 0.5 + 0.01 * native.abs(),
            "hlo {hlo} vs native {native}"
        );
    }
}

#[test]
fn predictor_tracks_workload_scale() {
    // trained predictor should predict high for high windows, low for low
    let Some(rt) = runtime() else { return };
    let low = vec![20.0f32; PRED_WINDOW];
    let high = vec![120.0f32; PRED_WINDOW];
    let p_low = rt.predict_load(&low).unwrap();
    let p_high = rt.predict_load(&high).unwrap();
    assert!(p_high > p_low, "predictor must track scale: {p_low} vs {p_high}");
    assert!((p_low - 20.0).abs() < 25.0, "low pred {p_low} too far from 20");
    assert!((p_high - 120.0).abs() < 60.0, "high pred {p_high} too far from 120");
}

#[test]
fn manifest_smape_in_paper_band() {
    // paper §VI-A: SMAPE ≈ 6 % — accept anything ≤ 12 %
    let Some(rt) = runtime() else { return };
    assert!(
        rt.manifest.predictor_smape < 0.12,
        "trained predictor SMAPE {} too high",
        rt.manifest.predictor_smape
    );
}

#[test]
fn hlo_policy_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let state = vec![0.25f32; STATE_DIM];
    let (a, av) = rt.policy_forward(&rt.policy_init, &state).unwrap();
    let (b, bv) = rt.policy_forward(&rt.policy_init, &state).unwrap();
    assert_eq!(a, b);
    assert_eq!(av, bv);
}

#[test]
fn opd_agent_over_hlo_produces_valid_configs() {
    use opd::agents::{Agent, OpdAgent};
    use opd::cluster::ClusterTopology;
    use opd::pipeline::{catalog, QosWeights};
    use opd::sim::Env;
    use opd::workload::predictor::LstmPredictor;
    use opd::workload::WorkloadKind;
    let Some(rt) = runtime() else { return };
    let rt = Arc::new(rt);
    let mut env = Env::from_workload(
        catalog::video_analytics().spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        3,
        // Env predictors are `Send` (DESIGN.md §9): the native mirror on
        // the artifact weights, matching what `opd` itself wires into Env
        Box::new(LstmPredictor::native(rt.predictor_weights.clone())),
        10,
        60,
        3.0,
    );
    let mut agent = OpdAgent::from_runtime(rt, 1);
    while !env.done() {
        let action = {
            let obs = env.observe();
            let a = agent.decide(&obs);
            obs.spec.validate_config(&a).unwrap();
            a
        };
        let step = env.step(&action);
        assert!(step.reward.is_finite());
    }
}
