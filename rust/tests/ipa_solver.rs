//! Determinism contract of the branch-and-bound IPA solver (DESIGN.md §10):
//! the pruned/memoized/warm-started fast path must return configurations
//! **bitwise identical** to the retained exhaustive reference on every
//! catalog preset across a demand × budget grid, the hysteresis allocation
//! memo must be invisible, and the trainer's expert episodes must be
//! bitwise unchanged by the solver swap.

use opd::agents::{Agent, IpaAgent, IpaSolver};
use opd::cluster::ClusterTopology;
use opd::pipeline::catalog::{self, Preset};
use opd::pipeline::{QosWeights, TaskConfig};
use opd::rl::{Trainer, TrainerConfig, TrainingHistory};
use opd::sim::Env;
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::WorkloadKind;

const DEMANDS: [f64; 4] = [5.0, 40.0, 80.0, 150.0];
const BUDGETS: [f64; 3] = [6.0, 16.0, 30.0];

fn assert_same(
    tag: &str,
    (a, sa): (Vec<TaskConfig>, f64),
    (b, sb): (Vec<TaskConfig>, f64),
) {
    assert_eq!(a, b, "{tag}: configurations diverged");
    assert_eq!(sa.to_bits(), sb.to_bits(), "{tag}: scores diverged");
}

/// Fast path ≡ exhaustive reference, with a FRESH solver per point (no
/// memo/warm carry-over) and with ONE solver reused across the whole grid
/// (memo + warm start active) — both must match exactly.
#[test]
fn pruned_matches_exhaustive_across_presets_and_grids() {
    for preset in [Preset::P1, Preset::P2, Preset::P3] {
        let spec = catalog::preset(preset).spec;
        let mut reused = IpaSolver::new(QosWeights::default());
        for demand in DEMANDS {
            for budget in BUDGETS {
                // P3's exhaustive reference walks 4^6 combos per point —
                // audit a 2×2 subgrid there to keep debug-mode test time
                // sane (perf_ipa sweeps the rest in release mode)
                if preset == Preset::P3 && (!(40.0..=80.0).contains(&demand) || budget < 16.0)
                {
                    continue;
                }
                let tag = format!("{preset:?} demand={demand} budget={budget}");
                let mut reference = IpaSolver::new(QosWeights::default());
                let want = reference.solve_exhaustive(&spec, demand, budget);
                let mut fresh = IpaSolver::new(QosWeights::default());
                assert_same(&tag, fresh.solve(&spec, demand, budget), want.clone());
                assert_same(&tag, reused.solve(&spec, demand, budget), want);
            }
        }
        assert!(
            reused.stats().pruned_bound + reused.stats().pruned_cores > 0,
            "{preset:?}: the grid should exercise both pruning rules"
        );
    }
}

/// P4 (8 stages × 4 variants = 65 536 combos) is the Fig. 6 worst case;
/// one exhaustive point keeps the test-suite runtime sane — `perf_ipa`
/// audits more P4 points in release mode.
#[test]
fn pruned_matches_exhaustive_on_p4_spot_check() {
    let spec = catalog::preset(Preset::P4).spec;
    let mut fast = IpaSolver::new(QosWeights::default());
    let mut slow = IpaSolver::new(QosWeights::default());
    let want = slow.solve_exhaustive(&spec, 80.0, 16.0);
    assert_same("P4 demand=80 budget=16", fast.solve(&spec, 80.0, 16.0), want);
    assert!(
        fast.stats().leaves < slow.stats().leaves / 2,
        "P4 should prune hard: {} vs {} leaves",
        fast.stats().leaves,
        slow.stats().leaves
    );
}

/// The hysteresis path: a memoized re-allocation of the previous variants
/// must equal a fresh ascent, feasible or not.
#[test]
fn allocate_memo_is_invisible() {
    let spec = catalog::preset(Preset::P2).spec;
    let mut memo = IpaSolver::new(QosWeights::default());
    let mut fresh = IpaSolver::new(QosWeights::default());
    fresh.exhaustive = true; // exhaustive mode never consults the memo
    let variants: Vec<Vec<usize>> =
        vec![vec![0, 0, 0, 0], vec![1, 2, 0, 1], vec![2, 2, 2, 2], vec![0, 2, 1, 0]];
    for demand in DEMANDS {
        for budget in [4.0, 16.0, 30.0] {
            for vs in &variants {
                // twice through the memoized solver: miss then hit
                for round in 0..2 {
                    let got = memo
                        .allocate(&spec, vs, demand, budget)
                        .map(|(c, s)| (c.to_vec(), s));
                    let want = fresh
                        .allocate(&spec, vs, demand, budget)
                        .map(|(c, s)| (c.to_vec(), s));
                    match (got, want) {
                        (None, None) => {}
                        (Some((gc, gs)), Some((wc, ws))) => {
                            assert_eq!(gc, wc, "round {round} {vs:?}");
                            assert_eq!(gs.to_bits(), ws.to_bits());
                        }
                        (g, w) => panic!("feasibility diverged: {g:?} vs {w:?}"),
                    }
                }
            }
        }
    }
    assert!(memo.stats().alloc_memo_hits > 0, "second rounds must hit the memo");
}

/// Warm-start is a pruning bound only: a drifting-demand solve sequence on
/// one solver (warm + memo active) must track the exhaustive reference at
/// every step.
#[test]
fn warm_started_sequence_tracks_exhaustive() {
    let spec = catalog::preset(Preset::P2).spec;
    let mut fast = IpaSolver::new(QosWeights::default());
    let mut slow = IpaSolver::new(QosWeights::default());
    let mut demand = 20.0;
    for step in 0..30 {
        // steady stretches (memo hits) interleaved with drifts (warm starts)
        if step % 3 == 0 {
            demand = 20.0 + (step as f64) * 4.7;
        }
        let tag = format!("step {step} demand={demand}");
        let want = slow.solve_exhaustive(&spec, demand, 30.0);
        assert_same(&tag, fast.solve(&spec, demand, 30.0), want);
    }
    let st = fast.stats();
    assert!(st.warm_bounds > 0, "drifting demand must exercise warm starts");
    assert!(st.solve_memo_hits > 0, "steady stretches must hit the solve memo");
}

fn decide_env(seed: u64) -> Env {
    Env::from_workload(
        catalog::video_analytics().spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        seed,
        Box::new(MovingMaxPredictor::default()),
        10,
        200,
        3.0,
    )
}

/// Full agent equivalence: `IpaAgent` (fast solver + hysteresis + the
/// reused-score bugfix) decides identically to the exhaustive reference
/// agent over a whole workload cycle.
#[test]
fn agent_decisions_are_solver_invariant() {
    let mut fast_env = decide_env(31);
    let mut slow_env = decide_env(31);
    let mut fast = IpaAgent::new();
    let mut slow = IpaAgent::exhaustive();
    while !fast_env.done() {
        let a = {
            let obs = fast_env.observe();
            fast.decide(&obs)
        };
        let b = {
            let obs = slow_env.observe();
            slow.decide(&obs)
        };
        assert_eq!(a, b, "t={}", fast_env.elapsed());
        let ra = fast_env.step(&a);
        let rb = slow_env.step(&b);
        assert_eq!(ra.reward.to_bits(), rb.reward.to_bits());
    }
}

fn history_bits(h: &TrainingHistory) -> Vec<u64> {
    let mut out = vec![h.diverged_updates as u64];
    for e in &h.episodes {
        out.push(e.episode as u64);
        out.push(e.expert as u64);
        out.push(e.mean_reward.to_bits());
        out.push(e.pi_loss.to_bits());
        out.push(e.v_loss.to_bits());
        out.push(e.entropy.to_bits());
        out.push(e.approx_kl.to_bits());
        out.push(e.diverged as u64);
    }
    out
}

fn train_factory(seed: u64) -> Env {
    Env::from_workload(
        catalog::by_name("P1").unwrap().spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        seed,
        Box::new(MovingMaxPredictor::default()),
        10,
        100,
        3.0,
    )
}

fn small_params(seed: u64) -> Vec<f32> {
    use opd::nn::spec::POLICY_PARAM_COUNT;
    use opd::util::prng::Pcg32;
    let mut rng = Pcg32::new(seed);
    (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
}

/// End-to-end expert-episode pin: training history AND learned parameters
/// are bitwise unchanged when the expert lanes run the exhaustive solver —
/// i.e. the branch-and-bound solver is invisible to Algorithm 2.
#[test]
fn trainer_output_is_bitwise_unchanged_by_the_fast_solver() {
    let run = |exhaustive: bool| {
        let tcfg = TrainerConfig {
            episodes: 4,
            expert_freq: 2, // episodes 2 and 4 are expert-driven
            epochs: 1,
            minibatches: 1,
            seed: 17,
            envs: 2,
            rollout_threads: 2,
            sync_every: 2,
            ..Default::default()
        };
        let mut trainer = Trainer::native(small_params(5), tcfg, train_factory);
        trainer.engine.expert_exhaustive = exhaustive;
        let history = trainer.train().unwrap().clone();
        let params: Vec<u32> = trainer.learner.params.iter().map(|p| p.to_bits()).collect();
        (history_bits(&history), params)
    };
    let (h_fast, p_fast) = run(false);
    let (h_slow, p_slow) = run(true);
    assert_eq!(h_fast, h_slow, "training history changed");
    assert_eq!(p_fast, p_slow, "learned parameters changed");
}
