//! Property tests over the batched, allocation-free decision hot path
//! (DESIGN.md §7): `policy_fwd_batch` over B states must be elementwise
//! equal to B independent `policy_fwd_native` calls, batched sampling must
//! be deterministic and batch-size-invariant, and the scratch buffers must
//! stop allocating after warm-up.

use opd::nn::math::{sample_masked, sample_masked_scratch};
use opd::nn::policy::policy_fwd_native;
use opd::nn::spec::*;
use opd::nn::workspace::Workspace;
use opd::util::prng::Pcg32;

fn random_params(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.04) as f32).collect()
}

fn random_states(seed: u64, batch: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..batch * STATE_DIM).map(|_| (rng.normal() * 0.5) as f32).collect()
}

/// A plausible mask layout: all replica/batch heads valid, a variant prefix
/// per task, tail tasks inactive.
fn masks(active_tasks: usize, variants: usize) -> (Vec<bool>, Vec<bool>) {
    let mut head = vec![false; LOGITS_DIM];
    let mut task = vec![false; MAX_TASKS];
    for t in 0..active_tasks {
        task[t] = true;
        let base = t * HEAD_DIM;
        for v in 0..variants {
            head[base + v] = true;
        }
        for f in 0..F_MAX {
            head[base + MAX_VARIANTS + f] = true;
        }
        for b in 0..N_BATCH {
            head[base + MAX_VARIANTS + F_MAX + b] = true;
        }
    }
    (head, task)
}

/// PROPERTY: the batched forward equals B independent native forwards
/// (elementwise ≤ 1e-6; the shared accumulation order makes them bitwise
/// equal in practice).
#[test]
fn prop_policy_fwd_batch_matches_independent_forwards() {
    let params = random_params(42);
    let mut ws = Workspace::new();
    for batch in [1usize, 2, 4, 7, 16, 33] {
        let states = random_states(1000 + batch as u64, batch);
        let (logits, values) = ws.policy_fwd_batch(&params, &states, batch);
        assert_eq!(logits.len(), batch * LOGITS_DIM);
        assert_eq!(values.len(), batch);
        for bi in 0..batch {
            let state = &states[bi * STATE_DIM..(bi + 1) * STATE_DIM];
            let (want_logits, want_value) = policy_fwd_native(&params, state);
            for (j, (a, b)) in logits[bi * LOGITS_DIM..(bi + 1) * LOGITS_DIM]
                .iter()
                .zip(&want_logits)
                .enumerate()
            {
                assert!(
                    (a - b).abs() <= 1e-6,
                    "batch {batch} row {bi} logit {j}: {a} vs {b}"
                );
            }
            assert!(
                (values[bi] - want_value).abs() <= 1e-6,
                "batch {batch} row {bi} value: {} vs {want_value}",
                values[bi]
            );
        }
    }
}

/// PROPERTY: with a fixed per-row seed, sampling from batched logits gives
/// the same picks no matter which batch size produced the logits — batching
/// is a pure evaluation-layout change, not a policy change.
#[test]
fn prop_batched_sampling_deterministic_across_batch_sizes() {
    let params = random_params(7);
    let n_rows = 16usize;
    let states = random_states(2024, n_rows);
    let (head_mask, task_mask) = masks(4, 3);

    // reference picks: each row evaluated alone
    let mut reference: Vec<Vec<(usize, f32)>> = Vec::new();
    for r in 0..n_rows {
        let (logits, _) = policy_fwd_native(&params, &states[r * STATE_DIM..][..STATE_DIM]);
        let mut rng = Pcg32::new(5000 + r as u64);
        let mut picks = Vec::new();
        for t in 0..MAX_TASKS {
            if !task_mask[t] {
                continue;
            }
            let base = t * HEAD_DIM;
            let mut off = 0;
            for d in HEAD_DIMS {
                picks.push(sample_masked(
                    &logits[base + off..base + off + d],
                    &head_mask[base + off..base + off + d],
                    &mut rng,
                ));
                off += d;
            }
        }
        reference.push(picks);
    }

    // the same rows evaluated through different batch shapes
    for batch in [1usize, 4, 16] {
        let mut ws = Workspace::new();
        let mut scratch = [0.0f32; MAX_HEAD_DIM];
        for chunk_start in (0..n_rows).step_by(batch) {
            let b = batch.min(n_rows - chunk_start);
            let chunk = &states[chunk_start * STATE_DIM..(chunk_start + b) * STATE_DIM];
            let (logits, _) = ws.policy_fwd_batch(&params, chunk, b);
            for bi in 0..b {
                let r = chunk_start + bi;
                let row = &logits[bi * LOGITS_DIM..(bi + 1) * LOGITS_DIM];
                let mut rng = Pcg32::new(5000 + r as u64);
                let mut k = 0usize;
                for t in 0..MAX_TASKS {
                    if !task_mask[t] {
                        continue;
                    }
                    let base = t * HEAD_DIM;
                    let mut off = 0;
                    for d in HEAD_DIMS {
                        let got = sample_masked_scratch(
                            &row[base + off..base + off + d],
                            &head_mask[base + off..base + off + d],
                            &mut rng,
                            &mut scratch[..d],
                        );
                        assert_eq!(
                            got, reference[r][k],
                            "batch {batch} row {r} head {k} diverged"
                        );
                        off += d;
                        k += 1;
                    }
                }
            }
        }
    }
}

/// PROPERTY: the workspace allocates only while growing to its steady-state
/// batch size; repeated forwards at or below that size never allocate.
#[test]
fn prop_workspace_allocation_free_after_warmup() {
    let params = random_params(3);
    let mut ws = Workspace::new();
    let states = random_states(9, 64);
    let _ = ws.policy_fwd_batch(&params, &states, 64);
    let warm = ws.grow_events();
    assert!(warm > 0, "first forward must have grown the buffers");
    for batch in [64usize, 16, 4, 1, 64] {
        for _ in 0..5 {
            let _ = ws.policy_fwd_batch(&params, &states[..batch * STATE_DIM], batch);
        }
    }
    assert_eq!(
        ws.grow_events(),
        warm,
        "forwards at ≤ warm batch size must not allocate"
    );
}
