//! Property tests over the batched, allocation-free decision hot path
//! (DESIGN.md §7 + §14): `policy_fwd_batch` over B states must be *bitwise*
//! equal to B independent scratch forwards at every batch size around the
//! 8-lane boundary (the §14 accumulation chains never see the batch),
//! batched sampling must be deterministic and batch-size-invariant,
//! fully-masked heads must take the guarded fallback, the batched LSTM
//! must match the sequential predictor bitwise at ragged batch sizes, and
//! the scratch buffers must stop allocating after warm-up.

use opd::nn::math::sample_masked_scratch;
use opd::nn::policy::{
    policy_fwd_scratch, predictor_fwd_batch_scratch, predictor_fwd_scratch, LstmBatchScratch,
    LstmScratch, PolicyScratch,
};
use opd::nn::spec::*;
use opd::nn::workspace::{select_heads, Workspace};
use opd::util::prng::Pcg32;

fn random_params(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.04) as f32).collect()
}

fn random_states(seed: u64, batch: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..batch * STATE_DIM).map(|_| (rng.normal() * 0.5) as f32).collect()
}

/// A plausible mask layout: all replica/batch heads valid, a variant prefix
/// per task, tail tasks inactive.
fn masks(active_tasks: usize, variants: usize) -> (Vec<bool>, Vec<bool>) {
    let mut head = vec![false; LOGITS_DIM];
    let mut task = vec![false; MAX_TASKS];
    for t in 0..active_tasks {
        task[t] = true;
        let base = t * HEAD_DIM;
        for v in 0..variants {
            head[base + v] = true;
        }
        for f in 0..F_MAX {
            head[base + MAX_VARIANTS + f] = true;
        }
        for b in 0..N_BATCH {
            head[base + MAX_VARIANTS + F_MAX + b] = true;
        }
    }
    (head, task)
}

/// PROPERTY (§14): the batched forward is BITWISE equal to B independent
/// single-state forwards, including every ragged batch size around the
/// 8-lane boundary — each output element's accumulation chain is fixed by
/// the lane contract and never sees the other rows.
#[test]
fn prop_policy_fwd_batch_matches_independent_forwards_bitwise() {
    let params = random_params(42);
    let mut ws = Workspace::new();
    let mut ps = PolicyScratch::default();
    for batch in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 16, 33] {
        let states = random_states(1000 + batch as u64, batch);
        let (logits, values) = ws.policy_fwd_batch(&params, &states, batch);
        assert_eq!(logits.len(), batch * LOGITS_DIM);
        assert_eq!(values.len(), batch);
        for bi in 0..batch {
            let state = &states[bi * STATE_DIM..(bi + 1) * STATE_DIM];
            let (want_logits, want_value) = policy_fwd_scratch(&params, state, &mut ps);
            for (j, (a, b)) in
                logits[bi * LOGITS_DIM..(bi + 1) * LOGITS_DIM].iter().zip(want_logits).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "batch {batch} row {bi} logit {j}: {a} vs {b}"
                );
            }
            assert_eq!(
                values[bi].to_bits(),
                want_value.to_bits(),
                "batch {batch} row {bi} value: {} vs {want_value}",
                values[bi]
            );
        }
    }
}

/// PROPERTY: with a fixed per-row seed, sampling from batched logits gives
/// the same picks no matter which batch size produced the logits — batching
/// is a pure evaluation-layout change, not a policy change.
#[test]
fn prop_batched_sampling_deterministic_across_batch_sizes() {
    let params = random_params(7);
    let n_rows = 16usize;
    let states = random_states(2024, n_rows);
    let (head_mask, task_mask) = masks(4, 3);

    // reference picks: each row evaluated alone
    let mut ps = PolicyScratch::default();
    let mut scratch = [0.0f32; MAX_HEAD_DIM];
    let mut reference: Vec<Vec<(usize, f32)>> = Vec::new();
    for r in 0..n_rows {
        let (logits, _) =
            policy_fwd_scratch(&params, &states[r * STATE_DIM..][..STATE_DIM], &mut ps);
        let mut rng = Pcg32::new(5000 + r as u64);
        let mut picks = Vec::new();
        for t in 0..MAX_TASKS {
            if !task_mask[t] {
                continue;
            }
            let base = t * HEAD_DIM;
            let mut off = 0;
            for d in HEAD_DIMS {
                picks.push(sample_masked_scratch(
                    &logits[base + off..base + off + d],
                    &head_mask[base + off..base + off + d],
                    &mut rng,
                    &mut scratch[..d],
                ));
                off += d;
            }
        }
        reference.push(picks);
    }

    // the same rows evaluated through different batch shapes
    for batch in [1usize, 4, 16] {
        let mut ws = Workspace::new();
        let mut scratch = [0.0f32; MAX_HEAD_DIM];
        for chunk_start in (0..n_rows).step_by(batch) {
            let b = batch.min(n_rows - chunk_start);
            let chunk = &states[chunk_start * STATE_DIM..(chunk_start + b) * STATE_DIM];
            let (logits, _) = ws.policy_fwd_batch(&params, chunk, b);
            for bi in 0..b {
                let r = chunk_start + bi;
                let row = &logits[bi * LOGITS_DIM..(bi + 1) * LOGITS_DIM];
                let mut rng = Pcg32::new(5000 + r as u64);
                let mut k = 0usize;
                for t in 0..MAX_TASKS {
                    if !task_mask[t] {
                        continue;
                    }
                    let base = t * HEAD_DIM;
                    let mut off = 0;
                    for d in HEAD_DIMS {
                        let got = sample_masked_scratch(
                            &row[base + off..base + off + d],
                            &head_mask[base + off..base + off + d],
                            &mut rng,
                            &mut scratch[..d],
                        );
                        assert_eq!(
                            got, reference[r][k],
                            "batch {batch} row {r} head {k} diverged"
                        );
                        off += d;
                        k += 1;
                    }
                }
            }
        }
    }
}

/// PROPERTY: a task whose variant head is FULLY masked takes the guarded
/// deterministic fallback (index 0, log-prob 0.0) through `select_heads` —
/// no RNG draw is consumed, the total log-prob stays finite, and greedy
/// selection agrees with sampling on the fallback index.
#[test]
fn fully_masked_heads_take_the_guarded_fallback() {
    let params = random_params(11);
    let mut ps = PolicyScratch::default();
    let state = random_states(77, 1);
    let (logits, _) = policy_fwd_scratch(&params, &state, &mut ps);
    let (mut head_mask, task_mask) = masks(3, 2);
    // fully mask task 1's variant head: no valid category remains
    for v in 0..MAX_VARIANTS {
        head_mask[HEAD_DIM + v] = false;
    }
    let mut idx = vec![0usize; ACT_DIM];
    let mut rng = Pcg32::new(123);
    let logp = select_heads(logits, &head_mask, &task_mask, false, &mut rng, &mut idx);
    assert!(logp.is_finite() && logp > -1.0e8, "fallback must not poison logp: {logp}");
    assert_eq!(idx[3], 0, "fully-masked head takes the index-0 fallback");
    let mut idx_g = vec![0usize; ACT_DIM];
    let mut rng_g = Pcg32::new(123);
    let logp_g = select_heads(logits, &head_mask, &task_mask, true, &mut rng_g, &mut idx_g);
    assert_eq!(idx_g[3], 0, "greedy agrees on the fallback index");
    assert!(logp_g.is_finite() && logp_g > -1.0e8);
}

/// PROPERTY (§14): the batched LSTM forward is bitwise equal to the
/// sequential predictor on every row for every ragged batch size around
/// the 8-lane boundary (LSTM_HIDDEN = 25 also exercises the scalar j-tail
/// of the lane matmul: 4H = 100 = 12×8 + 4).
#[test]
fn prop_batched_predictor_matches_sequential_bitwise() {
    let mut rng = Pcg32::new(31);
    let params: Vec<f32> =
        (0..PREDICTOR_PARAM_COUNT).map(|_| (rng.normal() * 0.3) as f32).collect();
    let mut single = LstmScratch::default();
    let mut batched = LstmBatchScratch::default();
    for batch in 1usize..=9 {
        let windows: Vec<f32> =
            (0..batch * PRED_WINDOW).map(|_| rng.uniform_range(0.0, 200.0) as f32).collect();
        let out = predictor_fwd_batch_scratch(&params, &windows, batch, &mut batched);
        for b in 0..batch {
            let want = predictor_fwd_scratch(
                &params,
                &windows[b * PRED_WINDOW..(b + 1) * PRED_WINDOW],
                &mut single,
            );
            assert_eq!(out[b].to_bits(), want.to_bits(), "batch {batch} row {b}");
        }
    }
}

/// PROPERTY: the workspace allocates only while growing to its steady-state
/// batch size; repeated forwards at or below that size never allocate.
#[test]
fn prop_workspace_allocation_free_after_warmup() {
    let params = random_params(3);
    let mut ws = Workspace::new();
    let states = random_states(9, 64);
    let _ = ws.policy_fwd_batch(&params, &states, 64);
    let warm = ws.grow_events();
    assert!(warm > 0, "first forward must have grown the buffers");
    for batch in [64usize, 16, 4, 1, 64] {
        for _ in 0..5 {
            let _ = ws.policy_fwd_batch(&params, &states[..batch * STATE_DIM], batch);
        }
    }
    assert_eq!(
        ws.grow_events(),
        warm,
        "forwards at ≤ warm batch size must not allocate"
    );
}
