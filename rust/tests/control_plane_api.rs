//! End-to-end v1 control-plane test: boot the leader with an empty cluster,
//! then — entirely over real HTTP, with no restarts — apply two distinct
//! pipelines, watch them share capacity, hot-swap one pipeline's agent,
//! inspect the cluster accounting, delete a pipeline, and shut the leader
//! down. The leader runs on the test thread (it is deliberately !Send); the
//! HTTP client drives it from a spawned thread.

use std::sync::Arc;

use opd::cluster::ClusterTopology;
use opd::serve::{
    http_delete, http_get, http_post, http_put, v1_router, ControlPlane, HttpServer, Leader,
    TenantFactory,
};
use opd::util::json::Json;

#[test]
fn v1_control_plane_end_to_end() {
    let cp = Arc::new(ControlPlane::new());
    let (mut leader, tx) = Leader::new(
        cp.clone(),
        ClusterTopology::paper_testbed(),
        1.0,
        TenantFactory::native(),
    );
    // no sim-time bound: the client ends the run via POST /v1/shutdown
    let router = v1_router(&cp, tx);
    let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
    let addr = server.addr;

    let client = std::thread::spawn(move || {
        // 1. the leader starts empty
        let (code, body) = http_get(&addr, "/v1/pipelines").unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert!(j.get("pipelines").unwrap().as_arr().unwrap().is_empty());

        // 2. create two distinct pipelines via POST
        let (code, body) = http_post(
            &addr,
            "/v1/pipelines",
            r#"{"name":"vid","pipeline":"video-analytics","workload":"steady-high","agent":"greedy","seed":7}"#,
        )
        .unwrap();
        assert_eq!(code, 201, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "greedy");
        assert_eq!(j.req_str("pipeline").unwrap(), "video-analytics");
        assert!(j.get("generation").unwrap().as_i64().unwrap() >= 1);

        let (code, body) = http_post(
            &addr,
            "/v1/pipelines",
            r#"{"name":"iot","pipeline":"iot-anomaly","workload":"steady-low","agent":"random","seed":3}"#,
        )
        .unwrap();
        assert_eq!(code, 201, "{body}");

        // duplicate POST → 409; unknown catalog entry → 400; bad JSON → 400
        let (code, _) =
            http_post(&addr, "/v1/pipelines", r#"{"name":"vid","pipeline":"video-analytics"}"#)
                .unwrap();
        assert_eq!(code, 409);
        let (code, _) =
            http_post(&addr, "/v1/pipelines", r#"{"name":"x","pipeline":"nope"}"#).unwrap();
        assert_eq!(code, 400);
        let (code, _) = http_post(&addr, "/v1/pipelines", "not json").unwrap();
        assert_eq!(code, 400);

        // 3. both show up in the list
        let (code, body) = http_get(&addr, "/v1/pipelines").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("pipelines").unwrap().as_arr().unwrap().len(), 2);

        // let the shared serving loop run both pipelines for a while
        std::thread::sleep(std::time::Duration::from_millis(400));

        // 4. hot-swap vid's agent greedy → ipa through the API; the swap
        // bumps the deployment generation so observers can tell a new brain
        // is driving the same pipeline
        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!(code, 200);
        let gen_before = Json::parse(&body).unwrap().get("generation").unwrap().as_i64().unwrap();
        let (code, body) =
            http_post(&addr, "/v1/pipelines/vid/agent", r#"{"agent":"ipa"}"#).unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "ipa");
        let gen_after = j.get("generation").unwrap().as_i64().unwrap();
        assert!(gen_after > gen_before, "swap must bump generation ({gen_before} → {gen_after})");
        // a follow-up GET reflects the bumped generation and the new agent,
        // and the pipeline keeps deciding under it
        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "ipa");
        assert!(j.get("generation").unwrap().as_i64().unwrap() >= gen_after);
        // swapping an unknown pipeline → 404; unknown agent → 400
        let (code, _) =
            http_post(&addr, "/v1/pipelines/zzz/agent", r#"{"agent":"ipa"}"#).unwrap();
        assert_eq!(code, 404);
        let (code, _) =
            http_post(&addr, "/v1/pipelines/vid/agent", r#"{"agent":"zzz"}"#).unwrap();
        assert_eq!(code, 400);
        // subsequent decisions use the new agent: give the loop time to run
        // at least one ipa decision round under the bumped generation
        std::thread::sleep(std::time::Duration::from_millis(300));
        let (_, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "ipa");
        assert!(j.get("generation").unwrap().as_i64().unwrap() >= gen_after);

        // 5. shared-capacity accounting in /v1/cluster
        let (code, body) = http_get(&addr, "/v1/cluster").unwrap();
        assert_eq!(code, 200);
        let cl = Json::parse(&body).unwrap();
        let cap = cl.req_f64("capacity").unwrap();
        let used = cl.req_f64("used").unwrap();
        assert!(used <= cap + 1e-6, "used {used} over capacity {cap}");
        let pipes = cl.get("pipelines").unwrap().as_arr().unwrap();
        assert_eq!(pipes.len(), 2);
        let sum: f64 = pipes.iter().map(|p| p.req_f64("cores").unwrap()).sum();
        assert!(
            (sum - used).abs() < 1e-6,
            "tenant cores {sum} must equal cluster used {used}"
        );
        assert!(
            pipes.iter().all(|p| p.req_f64("cores").unwrap() > 0.0),
            "every tenant holds a share: {body}"
        );

        // 6. per-pipeline status reflects the live serving loop
        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!(code, 200);
        let s = Json::parse(&body).unwrap();
        assert!(s.req_f64("avg_cost").unwrap() > 0.0, "{body}");
        assert!(s.req_f64("load_now").unwrap() > 0.0);
        assert!(s.get("generation").unwrap().as_i64().unwrap() >= 1);
        assert!(!s.get("config").unwrap().as_arr().unwrap().is_empty());

        // 7. declarative PUT updates in place (same server, no restart)
        let (code, body) = http_put(
            &addr,
            "/v1/pipelines/vid",
            r#"{"pipeline":"video-analytics","workload":"fluctuating","agent":"greedy"}"#,
        )
        .unwrap();
        assert_eq!(code, 200, "{body}");
        assert_eq!(Json::parse(&body).unwrap().req_str("agent").unwrap(), "greedy");

        // 8. delete iot; it is gone and its capacity is released
        let (code, _) = http_delete(&addr, "/v1/pipelines/iot").unwrap();
        assert_eq!(code, 200);
        let (code, _) = http_get(&addr, "/v1/pipelines/iot").unwrap();
        assert_eq!(code, 404);
        let (code, _) = http_delete(&addr, "/v1/pipelines/iot").unwrap();
        assert_eq!(code, 404, "double delete");
        let (code, body) = http_get(&addr, "/v1/cluster").unwrap();
        assert_eq!(code, 200);
        let cl = Json::parse(&body).unwrap();
        assert_eq!(cl.get("pipelines").unwrap().as_arr().unwrap().len(), 1);

        // 9. wrong method on a known path → 405 (not 404)
        let (code, _) = http_put(&addr, "/v1/pipelines", "{}").unwrap();
        assert_eq!(code, 405);

        // 10. the classic observability endpoints see the multi-tenant state
        let (code, body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("opd_pipelines"), "{body}");
        let (code, body) = http_get(&addr, "/state").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("\"pipelines\""));

        // 11. stop the leader over the API
        let (code, _) = http_post(&addr, "/v1/shutdown", "").unwrap();
        assert_eq!(code, 200);
    });

    leader.run(); // returns once the client POSTs /v1/shutdown
    client.join().unwrap();
    assert_eq!(leader.env.n_tenants(), 1, "vid survives, iot deleted");
    assert!(leader.env.now > 0.0, "the shared loop actually served traffic");
    server.shutdown();
}
