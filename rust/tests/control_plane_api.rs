//! End-to-end v1 control-plane test: boot the leader with an empty cluster,
//! then — entirely over real HTTP, with no restarts — apply two distinct
//! pipelines, watch them share capacity, hot-swap one pipeline's agent,
//! inspect the cluster accounting, delete a pipeline, and shut the leader
//! down. The leader runs on the test thread (it is deliberately !Send); the
//! HTTP client drives it from a spawned thread.

use std::sync::Arc;

use opd::cluster::ClusterTopology;
use opd::serve::leader::PER_TENANT_TELEMETRY_MAX;
use opd::serve::{
    http_delete, http_get, http_post, http_put, v1_router, ControlPlane, DeploySpec, HttpClient,
    HttpServer, Leader, TenantFactory,
};
use opd::util::json::Json;

#[test]
fn v1_control_plane_end_to_end() {
    let cp = Arc::new(ControlPlane::new());
    let (mut leader, tx) = Leader::new(
        cp.clone(),
        ClusterTopology::paper_testbed(),
        1.0,
        TenantFactory::native(),
    );
    // no sim-time bound: the client ends the run via POST /v1/shutdown
    let router = v1_router(&cp, tx);
    let server = HttpServer::start("127.0.0.1:0", router, 2).unwrap();
    let addr = server.addr;

    let client = std::thread::spawn(move || {
        // 1. the leader starts empty
        let (code, body) = http_get(&addr, "/v1/pipelines").unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert!(j.get("pipelines").unwrap().as_arr().unwrap().is_empty());

        // 2. create two distinct pipelines via POST
        let (code, body) = http_post(
            &addr,
            "/v1/pipelines",
            r#"{"name":"vid","pipeline":"video-analytics","workload":"steady-high","agent":"greedy","seed":7}"#,
        )
        .unwrap();
        assert_eq!(code, 201, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "greedy");
        assert_eq!(j.req_str("pipeline").unwrap(), "video-analytics");
        assert!(j.get("generation").unwrap().as_i64().unwrap() >= 1);

        let (code, body) = http_post(
            &addr,
            "/v1/pipelines",
            r#"{"name":"iot","pipeline":"iot-anomaly","workload":"steady-low","agent":"random","seed":3}"#,
        )
        .unwrap();
        assert_eq!(code, 201, "{body}");

        // duplicate POST → 409; unknown catalog entry → 400; bad JSON → 400
        let (code, _) =
            http_post(&addr, "/v1/pipelines", r#"{"name":"vid","pipeline":"video-analytics"}"#)
                .unwrap();
        assert_eq!(code, 409);
        let (code, _) =
            http_post(&addr, "/v1/pipelines", r#"{"name":"x","pipeline":"nope"}"#).unwrap();
        assert_eq!(code, 400);
        let (code, _) = http_post(&addr, "/v1/pipelines", "not json").unwrap();
        assert_eq!(code, 400);

        // 3. both show up in the list
        let (code, body) = http_get(&addr, "/v1/pipelines").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("pipelines").unwrap().as_arr().unwrap().len(), 2);

        // let the shared serving loop run both pipelines for a while
        std::thread::sleep(std::time::Duration::from_millis(400));

        // 4. hot-swap vid's agent greedy → ipa through the API; the swap
        // bumps the deployment generation so observers can tell a new brain
        // is driving the same pipeline
        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!(code, 200);
        let gen_before = Json::parse(&body).unwrap().get("generation").unwrap().as_i64().unwrap();
        let (code, body) =
            http_post(&addr, "/v1/pipelines/vid/agent", r#"{"agent":"ipa"}"#).unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "ipa");
        let gen_after = j.get("generation").unwrap().as_i64().unwrap();
        assert!(gen_after > gen_before, "swap must bump generation ({gen_before} → {gen_after})");
        // a follow-up GET reflects the bumped generation and the new agent,
        // and the pipeline keeps deciding under it
        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "ipa");
        assert!(j.get("generation").unwrap().as_i64().unwrap() >= gen_after);
        // swapping an unknown pipeline → 404; unknown agent → 400
        let (code, _) =
            http_post(&addr, "/v1/pipelines/zzz/agent", r#"{"agent":"ipa"}"#).unwrap();
        assert_eq!(code, 404);
        let (code, _) =
            http_post(&addr, "/v1/pipelines/vid/agent", r#"{"agent":"zzz"}"#).unwrap();
        assert_eq!(code, 400);
        // subsequent decisions use the new agent: give the loop time to run
        // at least one ipa decision round under the bumped generation
        std::thread::sleep(std::time::Duration::from_millis(300));
        let (_, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.req_str("agent").unwrap(), "ipa");
        assert!(j.get("generation").unwrap().as_i64().unwrap() >= gen_after);

        // 5. shared-capacity accounting in /v1/cluster
        let (code, body) = http_get(&addr, "/v1/cluster").unwrap();
        assert_eq!(code, 200);
        let cl = Json::parse(&body).unwrap();
        let cap = cl.req_f64("capacity").unwrap();
        let used = cl.req_f64("used").unwrap();
        assert!(used <= cap + 1e-6, "used {used} over capacity {cap}");
        let pipes = cl.get("pipelines").unwrap().as_arr().unwrap();
        assert_eq!(pipes.len(), 2);
        let sum: f64 = pipes.iter().map(|p| p.req_f64("cores").unwrap()).sum();
        assert!(
            (sum - used).abs() < 1e-6,
            "tenant cores {sum} must equal cluster used {used}"
        );
        assert!(
            pipes.iter().all(|p| p.req_f64("cores").unwrap() > 0.0),
            "every tenant holds a share: {body}"
        );

        // 6. per-pipeline status reflects the live serving loop
        let (code, body) = http_get(&addr, "/v1/pipelines/vid").unwrap();
        assert_eq!(code, 200);
        let s = Json::parse(&body).unwrap();
        assert!(s.req_f64("avg_cost").unwrap() > 0.0, "{body}");
        assert!(s.req_f64("load_now").unwrap() > 0.0);
        assert!(s.get("generation").unwrap().as_i64().unwrap() >= 1);
        assert!(!s.get("config").unwrap().as_arr().unwrap().is_empty());

        // 7. declarative PUT updates in place (same server, no restart)
        let (code, body) = http_put(
            &addr,
            "/v1/pipelines/vid",
            r#"{"pipeline":"video-analytics","workload":"fluctuating","agent":"greedy"}"#,
        )
        .unwrap();
        assert_eq!(code, 200, "{body}");
        assert_eq!(Json::parse(&body).unwrap().req_str("agent").unwrap(), "greedy");

        // 8. delete iot; it is gone and its capacity is released
        let (code, _) = http_delete(&addr, "/v1/pipelines/iot").unwrap();
        assert_eq!(code, 200);
        let (code, _) = http_get(&addr, "/v1/pipelines/iot").unwrap();
        assert_eq!(code, 404);
        let (code, _) = http_delete(&addr, "/v1/pipelines/iot").unwrap();
        assert_eq!(code, 404, "double delete");
        let (code, body) = http_get(&addr, "/v1/cluster").unwrap();
        assert_eq!(code, 200);
        let cl = Json::parse(&body).unwrap();
        assert_eq!(cl.get("pipelines").unwrap().as_arr().unwrap().len(), 1);

        // 9. wrong method on a known path → 405 (not 404)
        let (code, _) = http_put(&addr, "/v1/pipelines", "{}").unwrap();
        assert_eq!(code, 405);

        // 10. the classic observability endpoints see the multi-tenant state
        let (code, body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("opd_pipelines"), "{body}");
        let (code, body) = http_get(&addr, "/state").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("\"pipelines\""));

        // 11. chaos over real HTTP (DESIGN.md §13): bad plans are rejected,
        // a crash/recover pair is scheduled, the failure shows up in the
        // metrics, and the fleet self-heals back to a fully-up cluster
        let (code, _) = http_post(&addr, "/v1/chaos", r#"{"plan":"explode@1=0"}"#).unwrap();
        assert_eq!(code, 400, "unknown fault kind must be rejected");
        let (code, _) = http_post(&addr, "/v1/chaos", r#"{"nope":1}"#).unwrap();
        assert_eq!(code, 400, "missing 'plan' field must be rejected");
        let (code, body) =
            http_post(&addr, "/v1/chaos", r#"{"plan":"crash@0=2,recover@2=2"}"#).unwrap();
        assert_eq!(code, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("scheduled").unwrap().as_i64().unwrap(), 2, "{body}");
        std::thread::sleep(std::time::Duration::from_millis(400));
        let (_, text) = http_get(&addr, "/metrics").unwrap();
        assert!(text.contains("opd_node_failures_total"), "crash must be counted:\n{text}");
        assert!(text.contains("opd_nodes_up 3"), "recovery must bring all nodes back:\n{text}");
        assert!(text.contains("opd_degraded_tenants 0"), "fleet must self-heal:\n{text}");
        let (code, body) = http_get(&addr, "/v1/cluster").unwrap();
        assert_eq!(code, 200);
        let cl = Json::parse(&body).unwrap();
        assert!(
            cl.get("nodes")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .all(|n| n.get("up").unwrap().as_bool().unwrap()),
            "{body}"
        );

        // 12. stop the leader over the API
        let (code, _) = http_post(&addr, "/v1/shutdown", "").unwrap();
        assert_eq!(code, 200);
    });

    leader.run(); // returns once the client POSTs /v1/shutdown
    client.join().unwrap();
    assert_eq!(leader.env.n_tenants(), 1, "vid survives, iot deleted");
    assert!(leader.env.now > 0.0, "the shared loop actually served traffic");
    server.shutdown();
}

/// Cluster-scale e2e (DESIGN.md §12): hundreds of pipelines created,
/// decided, inspected, and torn down over a *single* keep-alive connection
/// while the leader keeps ticking. Exercises the due-wheel tick, the
/// usage-index placement, the lazy JSON routes, the streamed /state
/// snapshot, and the per-tenant telemetry cardinality gate end to end.
#[test]
fn many_tenants_over_one_keepalive_connection() {
    // past the gate, so the last creations happen with per-tenant telemetry off
    let n = PER_TENANT_TELEMETRY_MAX + 44;
    let survivors = PER_TENANT_TELEMETRY_MAX - 6;
    let cp = Arc::new(ControlPlane::new());
    let (mut leader, tx) = Leader::new(
        cp.clone(),
        ClusterTopology::uniform(128, 64.0),
        1.0,
        TenantFactory::native(),
    );
    let server = HttpServer::start("127.0.0.1:0", v1_router(&cp, tx), 4).unwrap();
    let addr = server.addr;

    let client = std::thread::spawn(move || {
        let mut c = HttpClient::connect(&addr).unwrap();
        for i in 0..n {
            let body = format!(
                r#"{{"name":"t-{i}","pipeline":"{}","agent":"{}","adapt_interval_secs":{},"seed":{i}}}"#,
                if i % 2 == 0 { "P1" } else { "iot-anomaly" },
                if i % 3 == 0 { "random" } else { "greedy" },
                5 + i % 7
            );
            let (code, resp) = c.post("/v1/pipelines", &body).unwrap();
            assert_eq!(code, 201, "create t-{i} failed: {resp}");
        }

        // every deployment is listed with a live generation
        let (code, body) = c.get("/v1/pipelines").unwrap();
        assert_eq!(code, 200);
        let pipes_json = Json::parse(&body).unwrap();
        let pipes = pipes_json.get("pipelines").unwrap().as_arr().unwrap();
        assert_eq!(pipes.len(), n);
        assert!(pipes.iter().all(|p| p.get("generation").unwrap().as_i64().unwrap() >= 1));

        // let the shared loop decide the fleet for a while
        std::thread::sleep(std::time::Duration::from_millis(500));

        // cluster accounting stays exact at scale
        let (code, body) = c.get("/v1/cluster").unwrap();
        assert_eq!(code, 200);
        let cl = Json::parse(&body).unwrap();
        let tenants = cl.get("pipelines").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), n);
        let used = cl.req_f64("used").unwrap();
        let sum: f64 = tenants.iter().map(|p| p.req_f64("cores").unwrap()).sum();
        assert!((sum - used).abs() < 1e-6, "tenant cores {sum} vs cluster used {used}");

        // the streamed /state snapshot agrees with the control-plane listing
        let (code, body) = c.get("/state").unwrap();
        assert_eq!(code, 200);
        let st = Json::parse(&body).unwrap();
        assert_eq!(st.get("pipelines").unwrap().as_arr().unwrap().len(), n);
        assert!(st.get("cluster").unwrap().get("now").is_some());

        // telemetry: aggregates always publish; per-tenant gauges are gated
        // above the cardinality cap, so t-{n-1} (created past the cap) must
        // not have one yet
        let (_, text) = c.get("/metrics").unwrap();
        assert!(text.contains("opd_pipelines"), "aggregate signals stay");
        let gated = format!("opd_qos{{pipeline=\"t-{}\"}}", n - 1);
        assert!(
            !text.contains(&gated),
            "per-tenant gauges must gate above {PER_TENANT_TELEMETRY_MAX} tenants"
        );

        // hot-swap one agent over the same connection (lazy JSON route)
        let (code, body) = c.post("/v1/pipelines/t-1/agent", r#"{"agent":"ipa"}"#).unwrap();
        assert_eq!(code, 200, "{body}");
        assert!(Json::parse(&body).unwrap().get("generation").unwrap().as_i64().unwrap() >= 2);

        // shrink below the gate (dropping the oldest tenants, keeping the
        // ones created while telemetry was gated); per-tenant signals resume
        for i in 0..(n - survivors) {
            let (code, _) = c.delete(&format!("/v1/pipelines/t-{i}")).unwrap();
            assert_eq!(code, 200, "delete t-{i}");
        }
        std::thread::sleep(std::time::Duration::from_millis(300));
        let (_, text) = c.get("/metrics").unwrap();
        assert!(
            text.contains(&gated),
            "per-tenant gauges must resume below the cardinality gate"
        );

        let (code, _) = c.post("/v1/shutdown", "{}").unwrap();
        assert_eq!(code, 200);
    });

    leader.run();
    client.join().unwrap();
    assert_eq!(leader.env.n_tenants(), survivors);
    assert!(leader.env.now > 0.0, "the shared loop actually served the fleet");
    server.shutdown();
}

/// Property sweep: the lazy path-scanning body parser must be
/// observationally identical to the full tree parser — same specs, same
/// error strings — across a generated v1 request corpus (field-order
/// permutations, whitespace, escapes, type confusion, truncation).
#[test]
fn lazy_and_tree_json_paths_agree_on_a_v1_corpus() {
    let mut corpus: Vec<String> = Vec::new();
    let names = ["vid", "a-b_c", "t\\u002d9", "bad name", ""];
    let pipelines = ["P1", "video-analytics", "nope"];
    let agents = ["greedy", "ipa", "zzz"];
    let intervals = ["5", "0", "-2", "3.5", "\"7\""];
    for (i, name) in names.iter().enumerate() {
        for (j, pipeline) in pipelines.iter().enumerate() {
            let agent = agents[(i + j) % agents.len()];
            let interval = intervals[(i * 2 + j) % intervals.len()];
            // two field orders, one with whitespace noise
            corpus.push(format!(
                r#"{{"name":"{name}","pipeline":"{pipeline}","agent":"{agent}","adapt_interval_secs":{interval},"seed":{i}}}"#
            ));
            corpus.push(format!(
                "{{\n  \"agent\": \"{agent}\",\n  \"pipeline\": \"{pipeline}\",\n  \"name\": \"{name}\"\n}}"
            ));
        }
    }
    // structural edge cases
    corpus.extend(
        [
            r#"{"name":"x","pipeline":"P1","workload":"steady-low"}"#,
            r#"{"name":"x","pipeline":"P1","workload":7}"#,
            r#"{"name":"x","pipeline":"P1","config":[{"variant":1,"replicas":2,"batch":4}]}"#,
            r#"{"name":"x","pipeline":"P1","config":"oops"}"#,
            r#"{"name":"x","name":"y","pipeline":"P1"}"#,
            r#"{"name":42,"pipeline":"P1"}"#,
            r#"{"pipeline":"P1","seed":-1}"#,
            r#"{"name":"x","pipeline":["P1"]}"#,
            r#"{"name":"x","pipeline":"P1""#,
            r#"[]"#,
            r#""just a string""#,
            r#"{}"#,
            "",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    for body in &corpus {
        for path_name in [None, Some("route-name")] {
            let tree = Json::parse(body)
                .map_err(|e| format!("invalid JSON body: {e}"))
                .and_then(|j| DeploySpec::from_json(&j, path_name));
            let lazy = DeploySpec::from_body(body, path_name);
            assert_eq!(lazy, tree, "lazy/tree divergence on {body:?} (path {path_name:?})");
        }
    }
}
