//! End-to-end CLI tests: drive the command surface the way a user would
//! (Args → command functions), including file outputs.

use opd::cli::args::Args;
use opd::cli::{cmd_compare, cmd_info, cmd_predict, cmd_simulate, cmd_train};
use opd::util::json::Json;

fn argv(s: &str) -> Args {
    Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>()).unwrap()
}

fn tmp(name: &str) -> String {
    std::env::temp_dir().join(name).to_str().unwrap().to_string()
}

#[test]
fn simulate_greedy_writes_summary_json() {
    let out = tmp("opd_e2e_sim.json");
    let args = argv(&format!(
        "simulate --pipeline P1 --workload steady-low --agent greedy --seed 3 \
         --cycle 100 --native --out {out}"
    ));
    cmd_simulate(&args).unwrap();
    let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(j.req_str("agent").unwrap(), "greedy");
    assert!(j.req_f64("avg_cost").unwrap() > 0.0);
    assert_eq!(j.get("qos_series").unwrap().as_arr().unwrap().len(), 100);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn simulate_all_agents_native() {
    for agent in ["random", "greedy", "ipa", "opd"] {
        let args = argv(&format!(
            "simulate --pipeline P1 --workload fluctuating --agent {agent} \
             --seed 1 --cycle 60 --native"
        ));
        cmd_simulate(&args).unwrap_or_else(|e| panic!("{agent}: {e:#}"));
    }
}

#[test]
fn compare_writes_four_results() {
    let out = tmp("opd_e2e_compare.json");
    let args = argv(&format!(
        "compare --pipeline P2 --workload steady-low --seed 4 --cycle 80 --native --out {out}"
    ));
    cmd_compare(&args).unwrap();
    let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    let arr = j.as_arr().unwrap();
    assert_eq!(arr.len(), 4);
    let agents: Vec<&str> = arr.iter().map(|x| x.req_str("agent").unwrap()).collect();
    assert_eq!(agents, vec!["random", "greedy", "ipa", "opd"]);
    let _ = std::fs::remove_file(&out);
}

#[test]
fn train_native_writes_checkpoint_and_history_then_resumes() {
    let out = tmp("opd_e2e_train.bin");
    let hist = tmp("opd_e2e_train_hist.json");
    // the native fused train step: no PJRT artifacts anywhere in this test
    let args = argv(&format!(
        "train --pipeline P1 --workload steady-low --seed 7 --episodes 2 --cycle 100 \
         --epochs 1 --minibatches 1 --native --out {out} --history {hist}"
    ));
    cmd_train(&args).unwrap();
    let params = opd::runtime::read_params(
        std::path::Path::new(&out),
        opd::nn::spec::POLICY_PARAM_COUNT,
    )
    .unwrap();
    assert!(params.iter().all(|p| p.is_finite()));
    assert!(
        std::path::Path::new(&format!("{out}.adam")).exists(),
        "checkpoint must include the optimizer sidecar"
    );
    let j = Json::parse(&std::fs::read_to_string(&hist).unwrap()).unwrap();
    let eps = j.as_arr().unwrap();
    assert_eq!(eps.len(), 2);
    assert!(eps[0].get("diverged").is_some(), "history records skipped updates");

    // resume from the checkpoint: one more episode, warm optimizer
    let args = argv(&format!(
        "train --pipeline P1 --workload steady-low --seed 8 --episodes 1 --cycle 100 \
         --epochs 1 --minibatches 1 --native --resume {out} --out {out}"
    ));
    cmd_train(&args).unwrap();

    let _ = std::fs::remove_file(&out);
    let _ = std::fs::remove_file(format!("{out}.adam"));
    let _ = std::fs::remove_file(&hist);
}

#[test]
fn predict_runs_native() {
    let args = argv("predict --workload fluctuating --secs 400 --seed 2 --native");
    cmd_predict(&args).unwrap();
}

#[test]
fn info_reports() {
    cmd_info(&argv("info")).unwrap();
}

#[test]
fn unknown_flags_rejected() {
    let args = argv("simulate --pipeline P1 --agent greedy --cycle 50 --native --frobnicate 9");
    assert!(cmd_simulate(&args).is_err());
}

#[test]
fn simulate_rejects_bad_pipeline() {
    let args = argv("simulate --pipeline NOPE --native");
    assert!(cmd_simulate(&args).is_err());
}

#[test]
fn serve_smoke_over_hlo_when_available() {
    // tiny serve cycle; exercises the HTTP control plane + decision loop.
    // uses native policy to stay artifact-independent.
    use opd::cli::cmd_serve;
    let args = argv(
        "serve --addr 127.0.0.1:0 --pipeline P1 --workload steady-low \
         --agent greedy --seed 1 --cycle 40 --native",
    );
    cmd_serve(&args).unwrap();
}
