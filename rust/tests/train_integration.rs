//! Integration tests for the PPO training path: the AOT train step must run,
//! update parameters, and improve the policy on a short task. Skipped when
//! artifacts are absent.

use std::sync::Arc;

use opd::cluster::ClusterTopology;
use opd::nn::spec::*;
use opd::pipeline::{catalog, QosWeights};
use opd::rl::{Minibatch, PpoLearner, Trainer, TrainerConfig};
use opd::runtime::OpdRuntime;
use opd::sim::Env;
use opd::util::prng::Pcg32;
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::WorkloadKind;

fn runtime() -> Option<Arc<OpdRuntime>> {
    match OpdRuntime::load(None) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

fn fake_minibatch(rng: &mut Pcg32) -> Minibatch {
    let mut mb = Minibatch {
        states: Vec::new(),
        actions: Vec::new(),
        old_logp: Vec::new(),
        adv: Vec::new(),
        ret: Vec::new(),
        head_mask: Vec::new(),
        task_mask: Vec::new(),
    };
    for _ in 0..TRAIN_BATCH {
        for _ in 0..STATE_DIM {
            mb.states.push((rng.normal() * 0.3) as f32);
        }
        for t in 0..MAX_TASKS {
            let _ = t;
            mb.actions.push(rng.below(MAX_VARIANTS as u32) as f32);
            mb.actions.push(rng.below(F_MAX as u32) as f32);
            mb.actions.push(rng.below(N_BATCH as u32) as f32);
        }
        // near-uniform policy logp ≈ -Σ log|head| per task
        let uni: f32 = -(MAX_TASKS as f32)
            * ((MAX_VARIANTS as f32).ln() + (F_MAX as f32).ln() + (N_BATCH as f32).ln());
        mb.old_logp.push(uni);
        mb.adv.push(rng.normal() as f32);
        mb.ret.push(rng.normal() as f32);
        for _ in 0..LOGITS_DIM {
            mb.head_mask.push(1.0);
        }
        for _ in 0..MAX_TASKS {
            mb.task_mask.push(1.0);
        }
    }
    mb
}

#[test]
fn train_step_executes_and_moves_params() {
    let Some(rt) = runtime() else { return };
    let mut learner = PpoLearner::new(rt);
    let before = learner.params.clone();
    let mut rng = Pcg32::new(3);
    let m = learner.update(&fake_minibatch(&mut rng)).unwrap();
    assert!(m.total_loss.is_finite());
    assert!(m.grad_norm > 0.0);
    assert!(m.entropy > 0.0, "near-uniform policy must have entropy");
    let delta: f32 = learner
        .params
        .iter()
        .zip(&before)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(delta > 0.0, "params must move");
    assert!(delta < 0.01, "single Adam step must be small, got {delta}");
    assert_eq!(learner.step, 1);
}

#[test]
fn value_loss_decreases_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut learner = PpoLearner::new(rt);
    let mut rng = Pcg32::new(4);
    let mb = fake_minibatch(&mut rng);
    let first = learner.update(&mb).unwrap();
    let mut last = first;
    for _ in 0..15 {
        last = learner.update(&mb).unwrap();
    }
    assert!(
        last.v_loss < first.v_loss,
        "value loss should fall on a fixed batch: {} -> {}",
        first.v_loss,
        last.v_loss
    );
}

#[test]
fn short_training_run_improves_reward() {
    let Some(rt) = runtime() else { return };
    let spec_name = "P1"; // tiny pipeline for a fast test
    let tcfg = TrainerConfig {
        episodes: 10,
        expert_freq: 3,
        epochs: 3,
        minibatches: 2,
        seed: 5,
        ..Default::default()
    };
    let rt2 = rt.clone();
    let mut trainer = Trainer::new(rt, tcfg, move |seed| {
        let _ = &rt2;
        Env::from_workload(
            catalog::by_name(spec_name).unwrap().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            WorkloadKind::Fluctuating,
            seed,
            Box::new(MovingMaxPredictor::default()),
            10,
            200,
            3.0,
        )
    });
    let history = trainer.train().unwrap().clone();
    assert_eq!(history.episodes.len(), 10);
    // losses finite throughout; reward roughly non-collapsing
    for e in &history.episodes {
        assert!(e.pi_loss.is_finite() && e.v_loss.is_finite());
    }
    let first3: f64 =
        history.episodes[..3].iter().map(|e| e.mean_reward).sum::<f64>() / 3.0;
    let last3: f64 =
        history.episodes[7..].iter().map(|e| e.mean_reward).sum::<f64>() / 3.0;
    assert!(
        last3 > first3 - 0.5,
        "training must not collapse: first3 {first3} last3 {last3}"
    );
    // expert episodes flagged per Algorithm 2 (every 3rd)
    assert!(history.episodes[2].expert);
    assert!(!history.episodes[0].expert);
}

#[test]
fn checkpoint_roundtrip() {
    let Some(rt) = runtime() else { return };
    let learner = PpoLearner::new(rt.clone());
    let path = std::env::temp_dir().join("opd_ckpt_test.bin");
    opd::runtime::write_params(&path, &learner.params).unwrap();
    let back = opd::runtime::read_params(&path, POLICY_PARAM_COUNT).unwrap();
    assert_eq!(back, learner.params);
    let _ = std::fs::remove_file(&path);
}
