//! Fault-tolerance integration tests (DESIGN.md §13): seeded chaos plans
//! replay bit-for-bit, node failures never drop a tenant, containers never
//! sit on a down node, and the self-healing repair loop converges once every
//! outage in a seeded plan has ended (seeded plans guarantee all outages end
//! by the horizon).

use opd::cluster::{ClusterTopology, FaultAction, FaultPlan};
use opd::pipeline::{catalog, QosWeights};
use opd::sim::{LoadSource, MultiEnv, Tenant, TenantHealth};
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::{WorkloadGen, WorkloadKind};

fn tenant(name: &str, pipeline: &str, kind: WorkloadKind, seed: u64) -> Tenant {
    Tenant::new(
        name,
        catalog::by_name(pipeline).unwrap().spec,
        Box::new(opd::agents::GreedyAgent::new()),
        QosWeights::default(),
        LoadSource::Gen(WorkloadGen::new(kind, seed)),
        Box::new(MovingMaxPredictor::default()),
        5,
    )
}

fn testbed_env() -> MultiEnv {
    let mut env = MultiEnv::new(ClusterTopology::paper_testbed(), 1.0);
    env.deploy(tenant("vid", "video-analytics", WorkloadKind::SteadyHigh, 7), None).unwrap();
    env.deploy(tenant("iot", "iot-anomaly", WorkloadKind::SteadyLow, 3), None).unwrap();
    env.deploy(tenant("p1", "P1", WorkloadKind::Fluctuating, 11), None).unwrap();
    env
}

/// Everything observable about a chaos run, bit-exact (f64 → to_bits).
fn fingerprint(env: &MultiEnv) -> Vec<u64> {
    let mut fp = vec![
        env.node_failures as u64,
        env.evacuations as u64,
        env.repairs as u64,
        env.tenant_kills as u64,
        env.degraded_count() as u64,
        env.pending_faults() as u64,
        env.store.topo.used().to_bits(),
        env.store.topo.capacity().to_bits(),
    ];
    for s in env.statuses() {
        fp.push(s.cores.to_bits());
        fp.push(s.avg_qos.to_bits());
        fp.push(s.avg_cost.to_bits());
        fp.push(s.degraded_secs.to_bits());
        fp.push(s.decisions as u64);
        fp.push(s.generation);
    }
    fp
}

/// Identical seed ⇒ identical run, down to the last bit of every counter,
/// core share, and QoS average; a different seed diverges.
#[test]
fn seeded_chaos_replays_bit_for_bit() {
    // pick seeds whose plans are non-empty and distinct, deterministically,
    // so the divergence half of the test cannot go vacuous
    let pick = |start: u64| {
        (start..start + 64)
            .find(|&s| FaultPlan::seeded(s, 3, 60.0, 15.0).len() >= 2)
            .expect("no non-empty seeded plan in 64 tries")
    };
    let a = pick(0);
    let b = pick(a + 1);
    let run = |seed: u64| {
        let mut env = testbed_env();
        let plan = FaultPlan::seeded(seed, 3, 60.0, 15.0);
        env.schedule_plan(&plan, 0.0);
        env.run_for(90);
        fingerprint(&env)
    };
    assert_eq!(run(a), run(a), "same seed must replay bit-for-bit");
    assert_ne!(run(a), run(b), "different seeds must diverge");
}

/// PROPERTY: under any seeded chaos plan, (a) no tenant is ever dropped,
/// (b) no container ever sits on a down node, (c) cluster usage never
/// exceeds effective capacity, and (d) once the plan's horizon has passed
/// (every seeded outage ends by then) the repair loop converges: every
/// tenant is Healthy again with a live share.
#[test]
fn chaos_never_drops_tenants_and_repair_converges() {
    const HORIZON: f64 = 50.0;
    for seed in 0..6u64 {
        let mut env = testbed_env();
        let n = env.n_tenants();
        let plan = FaultPlan::seeded(seed, 3, HORIZON, 12.0);
        env.schedule_plan(&plan, 0.0);
        // step tick-by-tick so the invariants hold at every instant, not
        // just at the end of the run
        for _ in 0..(HORIZON as usize + 40) {
            env.run_for(1);
            assert_eq!(env.n_tenants(), n, "seed {seed}: a tenant was dropped");
            for d in env.store.deployments() {
                for c in &d.containers {
                    assert!(
                        env.store.topo.nodes[c.node].up,
                        "seed {seed} t={}: container on down node {}",
                        env.now,
                        c.node
                    );
                }
            }
            assert!(
                env.store.topo.used() <= env.store.topo.capacity() + 1e-6,
                "seed {seed} t={}: used over effective capacity",
                env.now
            );
        }
        // settle: horizon passed, all nodes are back up, repairs done
        assert_eq!(env.pending_faults(), 0, "seed {seed}: plan not drained");
        assert!(env.store.topo.nodes.iter().all(|nd| nd.up), "seed {seed}: node left down");
        assert_eq!(env.degraded_count(), 0, "seed {seed}: repair loop did not converge");
        for s in env.statuses() {
            assert_eq!(s.health, TenantHealth::Healthy, "seed {seed}: {} not healthy", s.name);
            assert!(s.cores > 0.0, "seed {seed}: {} holds no share", s.name);
            assert!(!s.ready.is_empty(), "seed {seed}: {} has no ready stages", s.name);
        }
    }
}

/// A total outage parks every tenant (Pending, zero cores) without dropping
/// one; recovery brings the whole fleet back. Exercises the repair loop's
/// backoff path end to end through the public API only.
#[test]
fn total_outage_then_recovery_restores_the_fleet() {
    let mut env = MultiEnv::new(ClusterTopology::from_cores(&[4.0, 4.0]), 1.0);
    env.deploy(tenant("a", "P1", WorkloadKind::SteadyLow, 1), None).unwrap();
    env.deploy(tenant("b", "P1", WorkloadKind::SteadyLow, 2), None).unwrap();
    env.apply_fault(&FaultAction::NodeCrash(0));
    env.apply_fault(&FaultAction::NodeCrash(1));
    env.run_for(20);
    assert_eq!(env.n_tenants(), 2, "outage must never drop a tenant");
    assert_eq!(env.degraded_count(), 2);
    for s in env.statuses() {
        assert_eq!(s.cores, 0.0, "{} still holds cores with every node down", s.name);
        assert!(s.degraded_secs > 0.0);
    }
    env.apply_fault(&FaultAction::NodeRecover(0));
    env.apply_fault(&FaultAction::NodeRecover(1));
    env.run_for(30);
    assert_eq!(env.degraded_count(), 0, "fleet must heal after recovery");
    assert!(env.repairs >= 2, "both tenants must be re-placed");
    assert!(env.statuses().iter().all(|s| s.cores > 0.0));
}
