//! End-to-end online learning (DESIGN.md §11): boot the leader with a fleet
//! of OPD tenants and a background PPO trainer attached, serve simulated
//! traffic, and prove the full loop closes — live transitions stream to the
//! trainer, it publishes updated parameter generations, the fleet adopts
//! them at a tick boundary (bumping API-visible generations), and the
//! counters surface on /metrics. The leader runs on the test thread (it is
//! deliberately !Send); the HTTP client drives /metrics from a spawned
//! thread, exactly like production.

use std::sync::Arc;

use opd::agents::{baseline, Agent, OpdAgent};
use opd::cluster::ClusterTopology;
use opd::config::AgentKind;
use opd::nn::params_fingerprint;
use opd::rl::{OnlineConfig, OnlineTrainer};
use opd::serve::{http_get, v1_router, ControlPlane, DeploySpec, HttpServer, Leader, TenantFactory};
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::WorkloadKind;

/// An OPD-capable factory without PJRT: native policy agents sharing one
/// init vector (sampling, not greedy — the transition stream needs
/// exploration), baselines as usual.
fn opd_factory(init: Vec<f32>) -> TenantFactory {
    TenantFactory {
        make_agent: Box::new(move |kind, seed| match kind {
            AgentKind::Opd => {
                let mut a = OpdAgent::native(init.clone(), seed);
                a.greedy = false;
                Ok(Box::new(a) as Box<dyn Agent + Send>)
            }
            other => baseline(other, seed).ok_or_else(|| "unreachable".to_string()),
        }),
        make_predictor: Box::new(|| Box::new(MovingMaxPredictor::default())),
    }
}

fn deploy_spec(name: &str, pipeline: &str, seed: u64) -> DeploySpec {
    DeploySpec {
        name: name.into(),
        pipeline: pipeline.into(),
        workload: WorkloadKind::Fluctuating,
        agent: AgentKind::Opd,
        adapt_interval_secs: 5,
        seed,
        initial: None,
    }
}

#[test]
fn serve_learn_closes_the_loop() {
    let init: Vec<f32> = {
        let mut rng = opd::util::prng::Pcg32::new(42);
        (0..opd::nn::spec::POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
    };
    let init_fp = params_fingerprint(&init);

    let cp = Arc::new(ControlPlane::new());
    let (mut leader, tx) =
        Leader::new(cp.clone(), ClusterTopology::paper_testbed(), 1.0, opd_factory(init.clone()));
    let handle = OnlineTrainer::spawn(
        init,
        OnlineConfig { window: 16, min_batch: 8, epochs: 1, minibatches: 1, ..Default::default() },
    );
    leader.enable_online(&handle);
    leader.deploy(&deploy_spec("a", "P1", 1)).unwrap();
    leader.deploy(&deploy_spec("b", "P1", 2)).unwrap();
    leader.deploy(&deploy_spec("c", "iot-anomaly", 3)).unwrap();
    let server = HttpServer::start("127.0.0.1:0", v1_router(&cp, tx), 2).unwrap();
    let addr = server.addr;

    // phase 1: serve 120 s of simulated traffic — with interval 5 the three
    // tenants emit 3 transitions per round, far beyond one 16-wide window
    leader.max_secs = Some(120.0);
    leader.run();
    assert!(leader.env.online_transitions >= 16, "{}", leader.env.online_transitions);

    // the trainer runs off the leader's clock: wait (generously) for it to
    // chew through the queued windows and publish at least one generation
    let t0 = std::time::Instant::now();
    while handle.shared.generation() == 0 {
        assert!(t0.elapsed().as_secs() < 60, "trainer never published an update");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // phase 2: keep serving — the first tick adopts the published params and
    // the publish loop exports the online counters
    leader.max_secs = Some(140.0);
    leader.run();
    assert!(leader.env.policy_generation >= 1, "fleet never adopted a generation");
    assert!(leader.env.param_swaps >= 1);

    // the fleet now runs ONE shared post-update fingerprint (≠ the init)
    let fps: Vec<u64> =
        ["a", "b", "c"].iter().map(|n| leader.env.agent_fingerprint(n).unwrap()).collect();
    assert!(fps.iter().all(|&fp| fp == fps[0]), "fleet split: {fps:?}");
    assert_ne!(fps[0], init_fp, "adopted params must differ from the init");
    // adoption is API-visible: generation = 1 (deploy) + successful decide
    // applies + adoption bumps, so it must exceed deploy + decisions alone
    for n in ["a", "b", "c"] {
        let s = leader.env.status(n).unwrap();
        assert!(
            s.generation >= s.decisions as u64 + 2,
            "{n}: generation {} decisions {}",
            s.generation,
            s.decisions
        );
    }

    // the telemetry face saw it all
    let client = std::thread::spawn(move || {
        let (code, body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("opd_online_updates_total"), "{body}");
        assert!(body.contains("opd_online_transitions_total"), "{body}");
        let gen_line = body
            .lines()
            .find(|l| l.starts_with("opd_policy_generation"))
            .expect("generation gauge exported");
        let v: f64 = gen_line.split_whitespace().last().unwrap().parse().unwrap();
        assert!(v >= 1.0, "{gen_line}");
    });
    client.join().unwrap();

    // shutdown handshake: drop the env's sender, then join the trainer
    drop(leader.env.take_online().expect("hook attached"));
    let stats = handle.finish();
    assert!(stats.updates >= 1, "at least one online PPO update");
    assert!(stats.transitions as usize >= 16);
    assert!(stats.final_generation >= leader.env.policy_generation);
    server.shutdown();
}
