//! Cross-build kernel determinism harness (DESIGN.md §14).
//!
//! Each test prints `KERNEL_FP <name> 0x<hash>` — an FNV-1a fingerprint
//! over the exact output bits of one §14 kernel path. CI builds and runs
//! this file twice, once at the default x86-64 baseline (portable lane
//! structs) and once with `RUSTFLAGS="-C target-feature=+avx2,+fma"`
//! (AVX2 intrinsic lanes), and diffs the printed lines: any divergence is
//! a broken lane contract. The tests additionally assert in-process
//! batch- and thread-count invariance, so a single run is already a
//! determinism check on its own.
//!
//! Run with `--nocapture` (CI does) to surface the lines.

use opd::nn::math::{dense_batch_into, dense_bwd_batch_into, log_softmax_masked_into};
use opd::nn::policy::{
    policy_fwd_scratch, predictor_fwd_batch_scratch, LstmBatchScratch, PolicyScratch,
};
use opd::nn::spec::*;
use opd::nn::workspace::{params_fingerprint, Workspace};
use opd::rl::{Minibatch, PpoLearner};
use opd::util::prng::Pcg32;

fn fp(name: &str, data: &[f32]) -> u64 {
    let h = params_fingerprint(data);
    println!("KERNEL_FP {name} 0x{h:016x}");
    h
}

/// Dense forward + backward over shapes that straddle the 8-lane boundary
/// (odd widths, j-tails, the o = 1 fused-dot path) plus the policy-layer
/// shapes. Each batched row must be bitwise equal to the same row run at
/// batch 1 — the §14 chain never sees the batch.
#[test]
fn dense_kernel_fingerprints_and_batch_invariance() {
    let shapes = [
        (1usize, 7usize, 5usize),
        (3, 13, 9),
        (4, 25, 100),
        (16, 86, 128),
        (8, 128, 144),
        (6, 128, 1),
    ];
    let mut rng = Pcg32::new(101);
    for (batch, i, o) in shapes {
        let xs: Vec<f32> = (0..batch * i).map(|_| (rng.normal() * 0.5) as f32).collect();
        let w: Vec<f32> = (0..i * o).map(|_| (rng.normal() * 0.2) as f32).collect();
        let b: Vec<f32> = (0..o).map(|_| (rng.normal() * 0.1) as f32).collect();
        let mut out = vec![0.0f32; batch * o];
        dense_batch_into(&xs, batch, i, &w, &b, o, true, &mut out);
        fp(&format!("dense_fwd_{batch}x{i}x{o}"), &out);
        let mut row = vec![0.0f32; o];
        for bi in 0..batch {
            dense_batch_into(&xs[bi * i..(bi + 1) * i], 1, i, &w, &b, o, true, &mut row);
            assert_eq!(
                params_fingerprint(&row),
                params_fingerprint(&out[bi * o..(bi + 1) * o]),
                "shape ({batch},{i},{o}) row {bi}: batch changed the bits"
            );
        }
        let dy: Vec<f32> = (0..batch * o).map(|_| (rng.normal() * 0.3) as f32).collect();
        let mut gw = vec![0.0f32; i * o];
        let mut gb = vec![0.0f32; o];
        let mut dx = vec![0.0f32; batch * i];
        dense_bwd_batch_into(&xs, batch, i, &w, o, &dy, &mut gw, &mut gb, Some(&mut dx));
        fp(&format!("dense_bwd_gw_{batch}x{i}x{o}"), &gw);
        fp(&format!("dense_bwd_gb_{batch}x{i}x{o}"), &gb);
        fp(&format!("dense_bwd_dx_{batch}x{i}x{o}"), &dx);
    }
}

/// 64 policy states, evaluated in chunks of {1, 4, 16, 64} AND through the
/// single-state scratch path: one fingerprint for all five layouts.
#[test]
fn policy_forward_fingerprint_is_batch_invariant() {
    let mut rng = Pcg32::new(7);
    let params: Vec<f32> =
        (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.04) as f32).collect();
    let n = 64usize;
    let states: Vec<f32> = (0..n * STATE_DIM).map(|_| (rng.normal() * 0.5) as f32).collect();
    let mut reference: Option<u64> = None;
    for batch in [1usize, 4, 16, 64] {
        let mut ws = Workspace::new();
        let mut logits_all = Vec::with_capacity(n * LOGITS_DIM);
        let mut values_all = Vec::with_capacity(n);
        for start in (0..n).step_by(batch) {
            let chunk = &states[start * STATE_DIM..(start + batch) * STATE_DIM];
            let (logits, values) = ws.policy_fwd_batch(&params, chunk, batch);
            logits_all.extend_from_slice(logits);
            values_all.extend_from_slice(values);
        }
        logits_all.extend_from_slice(&values_all);
        let h = params_fingerprint(&logits_all);
        match reference {
            None => {
                reference = Some(fp("policy_fwd_64_states", &logits_all));
            }
            Some(want) => assert_eq!(h, want, "batch {batch} changed the forward bits"),
        }
    }
    let mut ps = PolicyScratch::default();
    let mut logits_all = Vec::with_capacity(n * LOGITS_DIM);
    let mut values_all = Vec::with_capacity(n);
    for s in 0..n {
        let (logits, value) =
            policy_fwd_scratch(&params, &states[s * STATE_DIM..(s + 1) * STATE_DIM], &mut ps);
        logits_all.extend_from_slice(logits);
        values_all.push(value);
    }
    logits_all.extend_from_slice(&values_all);
    assert_eq!(
        params_fingerprint(&logits_all),
        reference.unwrap(),
        "single-state scratch path diverged from the batched bits"
    );
}

/// 64 LSTM windows in chunks of {1, 4, 16, 64}: the recurrent lane chains
/// must make the predictions layout-independent to the bit.
#[test]
fn predictor_fingerprint_is_batch_invariant() {
    let mut rng = Pcg32::new(9);
    let params: Vec<f32> =
        (0..PREDICTOR_PARAM_COUNT).map(|_| (rng.normal() * 0.3) as f32).collect();
    let n = 64usize;
    let windows: Vec<f32> =
        (0..n * PRED_WINDOW).map(|_| rng.uniform_range(0.0, 200.0) as f32).collect();
    let mut reference: Option<u64> = None;
    for batch in [1usize, 4, 16, 64] {
        let mut s = LstmBatchScratch::default();
        let mut preds = Vec::with_capacity(n);
        for start in (0..n).step_by(batch) {
            let chunk = &windows[start * PRED_WINDOW..(start + batch) * PRED_WINDOW];
            preds.extend_from_slice(predictor_fwd_batch_scratch(&params, chunk, batch, &mut s));
        }
        let h = params_fingerprint(&preds);
        match reference {
            None => {
                reference = Some(fp("predictor_fwd_64_windows", &preds));
            }
            Some(want) => assert_eq!(h, want, "batch {batch} changed the predictor bits"),
        }
    }
}

/// Masked log-softmax over widths around the lane boundary, including a
/// fully-masked head (NEG_INF fill).
#[test]
fn log_softmax_fingerprint() {
    let mut rng = Pcg32::new(13);
    let mut all = Vec::new();
    for n in [1usize, 4, 7, 8, 9, 18] {
        let logits: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
        let mask: Vec<bool> = (0..n).map(|k| k % 3 != 1).collect();
        let mut out = vec![0.0f32; n];
        log_softmax_masked_into(&logits, &mask, &mut out);
        all.extend_from_slice(&out);
        log_softmax_masked_into(&logits, &vec![false; n], &mut out);
        all.extend_from_slice(&out);
    }
    fp("log_softmax_masked", &all);
}

/// Two full fused PPO updates on a TRAIN_BATCH minibatch: the resulting
/// parameter vector must carry the same bits for every worker-thread
/// count, and its fingerprint must match across target-feature builds.
#[test]
fn train_update_fingerprint_is_thread_invariant() {
    let mut rng = Pcg32::new(21);
    let params: Vec<f32> =
        (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.03) as f32).collect();
    let mb = Minibatch::synthetic(&mut rng, TRAIN_BATCH);
    let mut reference: Option<u64> = None;
    for threads in [1usize, 2, 4, 8] {
        let mut learner = PpoLearner::native(params.clone());
        learner.threads = threads;
        for _ in 0..2 {
            let _ = learner.update(&mb).unwrap();
        }
        let h = params_fingerprint(&learner.params);
        match reference {
            None => {
                reference = Some(fp("train_update_2steps", &learner.params));
            }
            Some(want) => assert_eq!(h, want, "threads {threads} changed the update bits"),
        }
    }
}
