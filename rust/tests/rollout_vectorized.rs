//! Determinism contract of the vectorized parallel rollout engine
//! (DESIGN.md §9): for fixed seeds, trajectories and training history are
//! bitwise identical for ANY lane count (`envs`) and ANY worker thread
//! count — concurrency is an execution detail, never a semantics knob —
//! and the engine's own machinery is allocation-free after warm-up.

use opd::cluster::ClusterTopology;
use opd::nn::spec::*;
use opd::pipeline::{catalog, QosWeights};
use opd::rl::{EpisodeSpec, RolloutEngine, Trainer, TrainerConfig, TrainingHistory};
use opd::sim::Env;
use opd::util::prng::Pcg32;
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::WorkloadKind;

fn factory(seed: u64) -> Env {
    Env::from_workload(
        catalog::by_name("P1").unwrap().spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        seed,
        Box::new(MovingMaxPredictor::default()),
        10,
        120,
        3.0,
    )
}

fn small_params(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.02) as f32).collect()
}

fn wave(n: usize, base_seed: u64, expert_freq: usize) -> Vec<EpisodeSpec> {
    (1..=n)
        .map(|episode| EpisodeSpec {
            episode,
            seed: base_seed + episode as u64,
            expert: expert_freq > 0 && episode % expert_freq == 0,
        })
        .collect()
}

/// Full bitwise fingerprint of a collected wave: every transition field of
/// every episode plus the per-episode metadata.
fn fingerprint(eng: &RolloutEngine) -> Vec<u64> {
    let mut out = Vec::new();
    for (i, r) in eng.results().iter().enumerate() {
        out.push(r.episode as u64);
        out.push(r.expert as u64);
        out.push(r.mean_reward.to_bits());
        out.push(r.bootstrap.to_bits());
        out.push(r.steps as u64);
        for tr in &eng.buffer(i).transitions {
            for x in &tr.state {
                out.push(x.to_bits() as u64);
            }
            for a in &tr.action_idx {
                out.push(*a as u64);
            }
            out.push(tr.logp.to_bits() as u64);
            out.push(tr.value.to_bits() as u64);
            out.push(tr.reward.to_bits());
            out.push(tr.head_mask.iter().fold(0u64, |acc, m| (acc << 1) | *m as u64));
            out.push(tr.task_mask.iter().fold(0u64, |acc, m| (acc << 1) | *m as u64));
        }
    }
    out
}

#[test]
fn trajectories_are_bitwise_invariant_over_lanes_and_threads() {
    let params = small_params(7);
    let w = wave(8, 42, 2); // expert episodes interleaved every 2nd
    let mut reference: Option<Vec<u64>> = None;
    for (lanes, threads) in [(1usize, 1usize), (3, 2), (8, 4), (8, 1)] {
        let mut eng = RolloutEngine::new(lanes, threads);
        eng.collect_wave(&params, &w, &mut factory);
        let fp = fingerprint(&eng);
        match &reference {
            None => reference = Some(fp),
            Some(want) => assert_eq!(
                &fp, want,
                "K={lanes} threads={threads} changed a trajectory bit"
            ),
        }
    }
}

fn history_bits(h: &TrainingHistory) -> Vec<u64> {
    let mut out = vec![h.diverged_updates as u64];
    for e in &h.episodes {
        out.push(e.episode as u64);
        out.push(e.expert as u64);
        out.push(e.mean_reward.to_bits());
        out.push(e.pi_loss.to_bits());
        out.push(e.v_loss.to_bits());
        out.push(e.entropy.to_bits());
        out.push(e.approx_kl.to_bits());
        out.push(e.diverged as u64);
    }
    out
}

fn train_with(envs: usize, threads: usize, sync_every: usize) -> (Vec<u64>, Vec<u32>) {
    let tcfg = TrainerConfig {
        episodes: 6,
        expert_freq: 3,
        epochs: 1,
        minibatches: 1,
        seed: 11,
        envs,
        rollout_threads: threads,
        sync_every,
        ..Default::default()
    };
    let mut trainer = Trainer::native(small_params(12), tcfg, factory);
    let history = trainer.train().unwrap().clone();
    let params: Vec<u32> = trainer.learner.params.iter().map(|p| p.to_bits()).collect();
    (history_bits(&history), params)
}

#[test]
fn training_history_and_params_are_lane_and_thread_invariant() {
    // fixed sync width → the update schedule is pinned; lanes/threads are
    // pure execution. K=1 IS the sequential path.
    let (h1, p1) = train_with(1, 1, 3);
    let (h3, p3) = train_with(3, 2, 3);
    let (h8, p8) = train_with(8, 4, 3);
    assert_eq!(h1, h3, "K=3 changed the training history");
    assert_eq!(h1, h8, "K=8 changed the training history");
    assert_eq!(p1, p3, "K=3 changed the learned parameters");
    assert_eq!(p1, p8, "K=8 changed the learned parameters");
}

#[test]
fn idle_lanes_do_not_change_per_episode_sync() {
    // sync_every = 1 (the paper's per-episode schedule): extra lanes sit
    // idle and the result is identical to the single-lane trainer
    let (h1, p1) = train_with(1, 1, 1);
    let (h4, p4) = train_with(4, 4, 1);
    assert_eq!(h1, h4);
    assert_eq!(p1, p4);
}

#[test]
fn sync_width_is_a_semantics_knob_unlike_lanes() {
    // sanity check of the contract's boundary: widening the sync window
    // (stale-params rollouts) is ALLOWED to change results — it is the one
    // knob that does
    let (h1, _) = train_with(1, 1, 1);
    let (h3, _) = train_with(1, 1, 3);
    assert_ne!(h1, h3, "sync_every should alter the update schedule");
}

#[test]
fn engine_is_allocation_free_after_warmup_with_threads() {
    let params = small_params(21);
    let mut eng = RolloutEngine::new(4, 4);
    eng.collect_wave(&params, &wave(6, 50, 2), &mut factory);
    let warm = eng.grow_events();
    for round in 0..3 {
        eng.collect_wave(&params, &wave(6, 200 + 10 * round, 2), &mut factory);
        assert_eq!(
            eng.grow_events(),
            warm,
            "wave {round}: warm engine must not allocate"
        );
    }
}
