//! Property-based tests over system invariants (hand-rolled generative
//! testing on the PCG substrate — proptest is unavailable offline).
//!
//! Each property runs hundreds of randomized cases with a fixed master seed,
//! so failures are reproducible.

use opd::cluster::{ClusterApi, ClusterTopology};
use opd::pipeline::catalog::{self, Preset};
use opd::pipeline::{pipeline_metrics, PipelineSpec, QosWeights, TaskConfig, BATCH_CHOICES, F_MAX};
use opd::rl::gae;
use opd::sim::{build_masks, build_state, decode_action, encode_action, Env};
use opd::util::prng::Pcg32;
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::WorkloadKind;

fn random_config(rng: &mut Pcg32, spec: &PipelineSpec) -> Vec<TaskConfig> {
    spec.tasks
        .iter()
        .map(|t| TaskConfig {
            variant: rng.below(t.n_variants() as u32) as usize,
            replicas: 1 + rng.below(F_MAX as u32) as usize,
            batch_idx: rng.below(BATCH_CHOICES.len() as u32) as usize,
        })
        .collect()
}

fn any_spec(rng: &mut Pcg32) -> PipelineSpec {
    let presets = Preset::all();
    let idx = rng.below(presets.len() as u32 + 2) as usize;
    match idx {
        0..=3 => catalog::preset(presets[idx]).spec,
        4 => catalog::video_analytics().spec,
        _ => catalog::iot_anomaly().spec,
    }
}

/// PROPERTY: after any apply (valid or infeasible), the deployed config
/// respects W_max, keeps ≥1 replica per stage, and node usage is consistent.
#[test]
fn prop_cluster_never_over_capacity() {
    let mut rng = Pcg32::new(1000);
    for case in 0..300 {
        let spec = any_spec(&mut rng);
        let mut api = ClusterApi::new(ClusterTopology::paper_testbed(), 3.0);
        let mut now = 0.0;
        for _ in 0..4 {
            let cfgs = random_config(&mut rng, &spec);
            let out = api.apply(&spec, &cfgs, now).unwrap_or_else(|e| {
                panic!("case {case}: apply failed: {e}");
            });
            assert!(
                spec.total_cores(&out.applied) <= api.topo.capacity() + 1e-6,
                "case {case}: over capacity"
            );
            assert!(out.applied.iter().all(|c| c.replicas >= 1));
            let used: f64 = api.containers().iter().map(|c| c.cores).sum();
            assert!((api.topo.used() - used).abs() < 1e-6, "case {case}: usage drift");
            // per-node capacity respected
            for n in &api.topo.nodes {
                assert!(n.cores_used <= n.cores_total + 1e-6);
            }
            now += 10.0;
        }
    }
}

/// PROPERTY: pipeline metrics are physically sane for any config/load.
#[test]
fn prop_pipeline_metrics_sane() {
    let mut rng = Pcg32::new(2000);
    for case in 0..500 {
        let spec = any_spec(&mut rng);
        let cfgs = random_config(&mut rng, &spec);
        let ready: Vec<usize> =
            cfgs.iter().map(|c| rng.below(c.replicas as u32 + 1) as usize).collect();
        let demand = rng.uniform_range(0.5, 400.0);
        let m = pipeline_metrics(&spec, &cfgs, &ready, demand);
        assert!(m.throughput <= demand + 1e-9, "case {case}: throughput exceeds demand");
        assert!(m.throughput >= 0.0);
        assert!(m.latency_ms > 0.0);
        assert!(m.cost > 0.0);
        assert!(m.accuracy > 0.0 && m.accuracy <= spec.n_tasks() as f64);
        assert!(m.excess <= demand + 1e-9, "excess can't exceed demand");
        for s in &m.stages {
            assert!(s.served <= s.arrival + 1e-9, "case {case}: stage served > arrival");
            assert!(s.served <= s.capacity + 1e-9);
        }
        // stage arrivals are non-increasing along a lossy chain
        for w in m.stages.windows(2) {
            assert!(w[1].arrival <= w[0].arrival + 1e-9, "case {case}: arrivals grew");
        }
    }
}

/// PROPERTY: adding a ready replica never increases unmet demand.
#[test]
fn prop_more_replicas_never_hurt_capacity() {
    let mut rng = Pcg32::new(3000);
    for _ in 0..300 {
        let spec = any_spec(&mut rng);
        let mut cfgs = random_config(&mut rng, &spec);
        let stage = rng.below(spec.n_tasks() as u32) as usize;
        cfgs[stage].replicas = cfgs[stage].replicas.min(F_MAX - 1);
        let ready: Vec<usize> = cfgs.iter().map(|c| c.replicas).collect();
        let demand = rng.uniform_range(50.0, 300.0);
        let m1 = pipeline_metrics(&spec, &cfgs, &ready, demand);
        let mut cfgs2 = cfgs.clone();
        cfgs2[stage].replicas += 1;
        let ready2: Vec<usize> = cfgs2.iter().map(|c| c.replicas).collect();
        let m2 = pipeline_metrics(&spec, &cfgs2, &ready2, demand);
        assert!(
            m2.excess <= m1.excess + 1e-9,
            "extra replica increased excess: {} -> {}",
            m1.excess,
            m2.excess
        );
    }
}

/// PROPERTY: action encode/decode roundtrips for every valid config.
#[test]
fn prop_action_roundtrip() {
    let mut rng = Pcg32::new(4000);
    for _ in 0..500 {
        let spec = any_spec(&mut rng);
        let cfgs = random_config(&mut rng, &spec);
        let idx = encode_action(&spec, &cfgs);
        let back = decode_action(&spec, &idx);
        assert_eq!(cfgs, back);
    }
}

/// PROPERTY: the state vector is finite and the masks agree with the spec.
#[test]
fn prop_state_and_masks_consistent() {
    let mut rng = Pcg32::new(5000);
    for case in 0..40 {
        let spec = any_spec(&mut rng);
        let kind = match case % 3 {
            0 => WorkloadKind::SteadyLow,
            1 => WorkloadKind::Fluctuating,
            _ => WorkloadKind::SteadyHigh,
        };
        let mut env = Env::from_workload(
            spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            kind,
            rng.next_u64(),
            Box::new(MovingMaxPredictor::default()),
            10,
            60,
            3.0,
        );
        for _ in 0..3 {
            let action = {
                let obs = env.observe();
                let s = build_state(&obs);
                assert!(s.iter().all(|x| x.is_finite()), "case {case}: non-finite state");
                let masks = build_masks(obs.spec);
                for t in 0..obs.spec.n_tasks() {
                    let base = t * opd::nn::spec::HEAD_DIM;
                    for v in 0..opd::nn::spec::MAX_VARIANTS {
                        assert_eq!(
                            masks.head[base + v],
                            v < obs.spec.tasks[t].n_variants(),
                            "case {case}: variant mask mismatch"
                        );
                    }
                }
                random_config(&mut rng, obs.spec)
            };
            env.step(&action);
        }
    }
}

/// PROPERTY: GAE is linear in (rewards, values) jointly scaled.
#[test]
fn prop_gae_linearity() {
    let mut rng = Pcg32::new(6000);
    for _ in 0..200 {
        let t = 1 + rng.below(50) as usize;
        let rewards: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
        let values: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
        let (adv1, _) = gae(&rewards, &values, 0.0, 0.99, 0.95);
        let r2: Vec<f64> = rewards.iter().map(|r| r * 2.0).collect();
        let v2: Vec<f64> = values.iter().map(|v| v * 2.0).collect();
        let (adv2, _) = gae(&r2, &v2, 0.0, 0.99, 0.95);
        for (a1, a2) in adv1.iter().zip(&adv2) {
            assert!((a2 - 2.0 * a1).abs() < 1e-9, "GAE must be linear");
        }
    }
}

/// PROPERTY: every agent's decision is a valid configuration on every
/// pipeline and workload.
#[test]
fn prop_agents_always_valid() {
    use opd::agents::{Agent, GreedyAgent, IpaAgent, OpdAgent, RandomAgent};
    let mut rng = Pcg32::new(7000);
    for case in 0..20 {
        let spec = any_spec(&mut rng);
        let mut env = Env::from_workload(
            spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            WorkloadKind::Fluctuating,
            rng.next_u64(),
            Box::new(MovingMaxPredictor::default()),
            10,
            40,
            3.0,
        );
        let params = vec![0.01f32; opd::nn::spec::POLICY_PARAM_COUNT];
        let mut agents: Vec<Box<dyn Agent>> = vec![
            Box::new(RandomAgent::new(case as u64)),
            Box::new(GreedyAgent::new()),
            Box::new(IpaAgent::new()),
            Box::new(OpdAgent::native(params, case as u64)),
        ];
        for agent in agents.iter_mut() {
            let obs = env.observe();
            let action = agent.decide(&obs);
            obs.spec
                .validate_config(&action)
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", agent.name()));
        }
    }
}

/// PROPERTY: deterministic replay — same seeds, same everything.
#[test]
fn prop_full_determinism() {
    use opd::agents::RandomAgent;
    use opd::sim::run_cycle;
    let run = |seed: u64| {
        let mut env = Env::from_workload(
            catalog::preset(Preset::P2).spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            WorkloadKind::Fluctuating,
            seed,
            Box::new(MovingMaxPredictor::default()),
            10,
            120,
            3.0,
        );
        let mut agent = RandomAgent::new(seed);
        let r = run_cycle(&mut env, &mut agent);
        (r.qos_series, r.cost_series)
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}
