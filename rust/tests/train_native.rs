//! Integration tests for the native fused PPO train step (DESIGN.md §8):
//! gradient correctness against central finite differences, shard-count
//! invariance of the threaded backward (including every ragged batch size
//! around the §14 lane boundary), bitwise-zero gradients for fully-masked
//! logit columns, allocation-freedom after warm-up, divergence skipping,
//! optimizer-state checkpointing, and a short end-to-end training run —
//! all on plain CPU, no PJRT artifacts.

use opd::cluster::ClusterTopology;
use opd::nn::spec::*;
use opd::nn::workspace::Workspace;
use opd::pipeline::{catalog, QosWeights};
use opd::rl::{
    eval_minibatch_native, ppo_loss_grad_native, ppo_loss_native, Minibatch, PpoLearner,
    StepScratch, Trainer, TrainerConfig,
};
use opd::sim::Env;
use opd::util::prng::Pcg32;
use opd::workload::predictor::MovingMaxPredictor;
use opd::workload::WorkloadKind;

fn small_params(seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..POLICY_PARAM_COUNT).map(|_| (rng.normal() * 0.03) as f32).collect()
}

/// Put `old_logp` within ±0.1 of the current policy's log-probs so the
/// importance ratio sits inside both the log-ratio clamp and the PPO clip —
/// the full pi-gradient path stays active and away from branch kinks.
fn realistic_old_logp(params: &[f32], mb: &mut Minibatch, rng: &mut Pcg32) {
    let mut ws = Workspace::new();
    let (logps, _) = eval_minibatch_native(params, mb, &mut ws);
    for (o, lp) in mb.old_logp.iter_mut().zip(&logps) {
        *o = lp + (rng.uniform() as f32 - 0.5) * 0.2;
    }
}

#[test]
fn gradient_matches_finite_difference_through_the_full_loss() {
    let params = small_params(3);
    let mut rng = Pcg32::new(4);
    let mut mb = Minibatch::synthetic(&mut rng, 4);
    realistic_old_logp(&params, &mut mb, &mut rng);

    let mut ws = Workspace::new();
    let mut scratch = StepScratch::default();
    let (metrics, grad) = ppo_loss_grad_native(&params, &mb, &mut ws, &mut scratch, 1);
    assert!(metrics.total_loss.is_finite());
    let grad = grad.to_vec();

    // sampled parameters from every region of the layout
    let l = &opd::nn::policy::POLICY_LAYOUT;
    let mut idxs = vec![l.fc_in_b + 3, l.head_b + 11, l.value_b];
    let mut pick = Pcg32::new(5);
    for (base, len) in [
        (l.fc_in_w, STATE_DIM * HIDDEN),
        (l.res[0].0, HIDDEN * HIDDEN),
        (l.res[1].2, HIDDEN * HIDDEN),
        (l.res[2].0, HIDDEN * HIDDEN),
        (l.head_w, HIDDEN * LOGITS_DIM),
        (l.value_w, HIDDEN),
    ] {
        for _ in 0..6 {
            idxs.push(base + pick.below(len as u32) as usize);
        }
    }
    let mut loose_misses = 0usize;
    for &k in &idxs {
        let eps = 5e-3f32;
        let mut pp = params.clone();
        pp[k] += eps;
        let mut pm = params.clone();
        pm[k] -= eps;
        let span = (pp[k] - pm[k]) as f64; // the actual f32 step taken
        let hi = ppo_loss_native(&pp, &mb, &mut ws, &mut scratch).total_loss;
        let lo = ppo_loss_native(&pm, &mb, &mut ws, &mut scratch).total_loss;
        let fd = (hi - lo) / span;
        let g = grad[k] as f64;
        let scale = g.abs().max(fd.abs()).max(0.5);
        let err = (fd - g).abs();
        // ~1e-3 relative in the common case; the odd coordinate can sit
        // near a ReLU kink inside the FD interval
        if err > 2e-3 * scale {
            loose_misses += 1;
            assert!(err < 5e-2 * scale, "param {k}: fd {fd} vs analytic {g}");
        }
    }
    assert!(loose_misses <= 2, "{loose_misses}/{} params off beyond 2e-3 relative", idxs.len());
}

#[test]
fn update_is_shard_count_invariant_bitwise() {
    let params = small_params(7);
    let mut rng = Pcg32::new(8);
    let mut mb = Minibatch::synthetic(&mut rng, 24); // 3 backward chunks
    realistic_old_logp(&params, &mut mb, &mut rng);

    let mut single = PpoLearner::native(params.clone());
    single.threads = 1;
    let mut sharded = PpoLearner::native(params);
    sharded.threads = 4;
    for step in 0..3 {
        let a = single.update(&mb).unwrap();
        let b = sharded.update(&mb).unwrap();
        assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "step {step} grad norm");
        let pa: Vec<u32> = single.params.iter().map(|p| p.to_bits()).collect();
        let pb: Vec<u32> = sharded.params.iter().map(|p| p.to_bits()).collect();
        assert_eq!(pa, pb, "step {step}: thread count changed the update");
    }
}

/// §14 lane boundary sweep: the chunked backward must be bitwise
/// thread-count-invariant at EVERY ragged batch size 1..=9, not just at
/// chunk multiples — the chunk structure is fixed by BWD_CHUNK_ROWS and
/// each element's lane chain ignores how rows are sharded.
#[test]
fn update_is_thread_invariant_at_ragged_batches() {
    for rows in 1usize..=9 {
        let params = small_params(40 + rows as u64);
        let mut rng = Pcg32::new(50 + rows as u64);
        let mut mb = Minibatch::synthetic(&mut rng, rows);
        realistic_old_logp(&params, &mut mb, &mut rng);
        let mut reference: Option<(u32, Vec<u32>)> = None;
        for threads in [1usize, 2, 4, 8] {
            let mut learner = PpoLearner::native(params.clone());
            learner.threads = threads;
            let m = learner.update(&mb).unwrap();
            let bits: Vec<u32> = learner.params.iter().map(|p| p.to_bits()).collect();
            match &reference {
                None => reference = Some((m.grad_norm.to_bits(), bits)),
                Some((gn, want)) => {
                    assert_eq!(m.grad_norm.to_bits(), *gn, "rows {rows} threads {threads}");
                    assert_eq!(&bits, want, "rows {rows} threads {threads} changed the update");
                }
            }
        }
    }
}

/// The §14 lane kernels drop the old `xv == 0.0` input skips; this pins the
/// contract the skips used to provide end to end: logit columns masked in
/// EVERY row (and whole deactivated tasks) get bitwise-zero head
/// gradients — ±0.0 lane terms combine to +0.0 through the fixed pairwise
/// tree, and mean/clip scaling keeps exact zeros exact.
#[test]
fn fully_masked_logit_columns_get_bitwise_zero_gradients() {
    let rows = 8usize;
    let params = small_params(61);
    let mut rng = Pcg32::new(62);
    let mut mb = Minibatch::synthetic(&mut rng, rows);
    for r in 0..rows {
        // mask variant 2 of task 0 everywhere; steer its action off the column
        mb.head_mask[r * LOGITS_DIM + 2] = 0.0;
        mb.actions[r * ACT_DIM] = 0.0;
        // deactivate task 5 entirely
        mb.task_mask[r * MAX_TASKS + 5] = 0.0;
    }
    realistic_old_logp(&params, &mut mb, &mut rng);
    let mut ws = Workspace::new();
    let mut scratch = StepScratch::default();
    let (metrics, grad) = ppo_loss_grad_native(&params, &mb, &mut ws, &mut scratch, 2);
    assert!(metrics.total_loss.is_finite());
    let l = &opd::nn::policy::POLICY_LAYOUT;
    for k in 0..HIDDEN {
        assert_eq!(
            grad[l.head_w + k * LOGITS_DIM + 2].to_bits(),
            0,
            "head_w row {k}, masked column 2 must be exactly zero"
        );
        for j in 5 * HEAD_DIM..6 * HEAD_DIM {
            assert_eq!(
                grad[l.head_w + k * LOGITS_DIM + j].to_bits(),
                0,
                "head_w row {k}, deactivated-task column {j} must be exactly zero"
            );
        }
    }
    assert_eq!(grad[l.head_b + 2].to_bits(), 0, "head_b masked column 2");
    for j in 5 * HEAD_DIM..6 * HEAD_DIM {
        assert_eq!(grad[l.head_b + j].to_bits(), 0, "head_b deactivated-task column {j}");
    }
}

#[test]
fn update_native_learns_a_fixed_minibatch() {
    let params = small_params(11);
    let mut rng = Pcg32::new(12);
    let mut mb = Minibatch::synthetic(&mut rng, 16);
    realistic_old_logp(&params, &mut mb, &mut rng);

    let mut learner = PpoLearner::native(params.clone());
    let first = learner.update(&mb).unwrap();
    assert!(!first.diverged);
    assert!(first.grad_norm > 0.0);
    assert!(first.entropy > 0.0, "near-uniform policy must have entropy");
    let mut last = first;
    for _ in 0..11 {
        last = learner.update(&mb).unwrap();
    }
    assert_eq!(learner.step, 12);
    assert!(
        last.v_loss < first.v_loss,
        "value loss should fall on a fixed batch: {} -> {}",
        first.v_loss,
        last.v_loss
    );
    assert!(
        last.total_loss < first.total_loss,
        "total loss should fall on a fixed batch: {} -> {}",
        first.total_loss,
        last.total_loss
    );
    let delta: f32 = learner
        .params
        .iter()
        .zip(&params)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(delta > 0.0, "params must move");
    assert!(delta < 0.05, "Adam steps stay small, got {delta}");
}

#[test]
fn train_step_is_allocation_free_after_warmup() {
    let params = small_params(17);
    let mut rng = Pcg32::new(18);
    let mb = Minibatch::synthetic(&mut rng, TRAIN_BATCH);
    let mut learner = PpoLearner::native(params);
    learner.threads = 2;
    let _ = learner.update(&mb).unwrap();
    let warm = learner.grow_events();
    for _ in 0..4 {
        let _ = learner.update(&mb).unwrap();
    }
    assert_eq!(learner.grow_events(), warm, "steady-state updates must not allocate");
}

#[test]
fn partial_final_minibatch_trains() {
    let params = small_params(21);
    let mut rng = Pcg32::new(22);
    let mut mb = Minibatch::synthetic(&mut rng, 7); // not a multiple of anything
    realistic_old_logp(&params, &mut mb, &mut rng);
    let mut learner = PpoLearner::native(params.clone());
    let m = learner.update(&mb).unwrap();
    assert!(!m.diverged);
    assert!(m.total_loss.is_finite() && m.grad_norm > 0.0);
    assert_eq!(learner.step, 1);
    assert!(learner.params != params, "partial minibatch must still update");
}

#[test]
fn diverged_minibatch_is_skipped_not_fatal() {
    let params = small_params(27);
    let mut rng = Pcg32::new(28);
    let mut mb = Minibatch::synthetic(&mut rng, 8);
    mb.adv[3] = f32::NAN; // poisons the normalized advantages → NaN loss
    let mut learner = PpoLearner::native(params.clone());
    let m = learner.update(&mb).unwrap();
    assert!(m.diverged, "non-finite loss must be flagged");
    assert_eq!(learner.step, 0, "diverged update must not advance the step");
    assert_eq!(learner.params, params, "diverged update must not touch params");
    // the learner keeps working on the next (healthy) minibatch
    let mut healthy = Minibatch::synthetic(&mut rng, 8);
    realistic_old_logp(&params, &mut healthy, &mut rng);
    let m2 = learner.update(&healthy).unwrap();
    assert!(!m2.diverged);
    assert_eq!(learner.step, 1);
}

fn tiny_env(seed: u64) -> Env {
    Env::from_workload(
        catalog::by_name("P1").unwrap().spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        seed,
        Box::new(MovingMaxPredictor::default()),
        10,
        120,
        3.0,
    )
}

#[test]
fn native_two_episode_training_runs_end_to_end() {
    let tcfg = TrainerConfig {
        episodes: 2,
        expert_freq: 2, // episode 2 is expert-driven: covers both rollout paths
        epochs: 1,
        minibatches: 1,
        seed: 9,
        ..Default::default()
    };
    let init = small_params(33);
    let mut trainer = Trainer::native(init.clone(), tcfg, tiny_env);
    let history = trainer.train().unwrap().clone();
    assert_eq!(history.episodes.len(), 2);
    assert!(!history.episodes[0].expert);
    assert!(history.episodes[1].expert);
    for e in &history.episodes {
        assert!(e.pi_loss.is_finite() && e.v_loss.is_finite(), "episode {}", e.episode);
    }
    assert_eq!(history.diverged_updates, 0);
    assert!(trainer.learner.params != init, "training must move the params");
    assert_eq!(trainer.learner.step, 2, "2 episodes × 1 epoch × 1 minibatch");

    // checkpoint: params blob + optimizer sidecar, reloadable
    let path = std::env::temp_dir().join("opd_native_train_ckpt.bin");
    let path = path.to_str().unwrap().to_string();
    trainer.save_checkpoint(&path).unwrap();
    assert!(std::path::Path::new(&format!("{path}.adam")).exists());
    let mut resumed = PpoLearner::native(small_params(34));
    resumed.load_checkpoint(&path).unwrap();
    assert_eq!(resumed.params, trainer.learner.params);
    assert_eq!(resumed.step, 2);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(format!("{path}.adam"));
}
