//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this vendored
//! shim implements the exact subset of anyhow's API the `opd` crate uses:
//! `Error`, `Result<T>`, the `anyhow!` macro, and the `Context` extension
//! trait for `Result`. Semantics mirror the real crate where it matters:
//!
//! * `Display` prints the outermost message only; the alternate form (`{:#}`)
//!   prints the full context chain joined by `": "`.
//! * `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion (what makes `?` work on
//!   io/parse errors) cannot overlap with an identity conversion.

use std::fmt;

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error: `frames[0]` is the outermost context, the last
/// frame is the root cause.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Root-cause message (the innermost frame).
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }

    /// Wrap with an outer context frame (used by the `Context` trait).
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.frames.insert(0, ctx.to_string());
        self
    }

    /// Number of frames (outermost context first).
    pub fn chain_len(&self) -> usize {
        self.frames.len()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

/// `?`-conversion from any std error; the source chain is flattened into
/// context frames so `{:#}` shows the full causal story.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// `anyhow!`: a formatted message, a bare displayable value, or fmt + args.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let n = 3;
        let a: Error = anyhow!("plain");
        let b: Error = anyhow!("count {n}");
        let c: Error = anyhow!("count {}, {}", n, "x");
        let d: Error = anyhow!(String::from("owned"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "count 3");
        assert_eq!(c.to_string(), "count 3, x");
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn context_chain_and_alternate_display() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        let e = Result::<(), Error>::Err(e)
            .map_err(|e| e.context("loading runtime"))
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "loading runtime: reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<u32, std::io::Error> = Ok(7);
        let out = r
            .with_context(|| -> String { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(out, 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let _ = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(1)
        }
        let e = inner().unwrap_err();
        assert!(e.chain_len() >= 1);
        assert!(!format!("{e:?}").is_empty());
    }
}
