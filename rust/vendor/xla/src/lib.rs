//! Offline stub of the `xla` crate (PJRT / xla_extension bindings).
//!
//! The build image ships neither the xla_extension shared library nor network
//! access to fetch the real bindings, so this stub provides the exact type
//! surface `opd::runtime` compiles against while reporting the PJRT runtime
//! as unavailable at the first entry point (`PjRtClient::cpu`). Every caller
//! in `opd` already treats runtime errors as "fall back to the pure-rust
//! mirrors in nn/", so a stubbed build degrades to the documented
//! no-artifacts behaviour instead of failing to compile.
//!
//! On a machine with the PJRT toolchain, point `rust/Cargo.toml`'s `xla`
//! dependency at the real crate; no `opd` source changes are needed.

use std::fmt;

/// Error type matching the real crate's role in `anyhow` context chains.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT runtime not available in this build \
                 (offline xla stub; native mirrors are used instead)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait ElementType {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// A PJRT device handle (never constructed by the stub).
pub struct PjRtDevice {
    _private: (),
}

/// A device-resident buffer (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal (never constructed by the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// The PJRT client. `cpu()` is the single entry point, and in the stub it
/// fails immediately with an actionable message.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("not available"), "{msg}");
        assert!(msg.contains("native mirrors"), "{msg}");
    }

    #[test]
    fn hlo_parsing_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
