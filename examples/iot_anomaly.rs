//! IoT anomaly-detection scenario: a 3-stage ingest → featurize →
//! detect-anomaly pipeline under steady HIGH load (the paper's Fig. 4c/5c
//! regime, where the 30-core resource ceiling binds and the cost/QoS of the
//! non-random algorithms converge).
//!
//! Also demonstrates config introspection: prints the deployed configuration
//! the winning agent settles on.
//!
//! Run: cargo run --release --example iot_anomaly

use std::rc::Rc;

use opd::agents::Agent;
use opd::cli::{make_agent, make_env_predictor};
use opd::cluster::ClusterTopology;
use opd::config::AgentKind;
use opd::pipeline::{catalog, QosWeights};
use opd::runtime::OpdRuntime;
use opd::sim::{run_cycle, Env};
use opd::workload::{Trace, WorkloadGen, WorkloadKind};

fn main() {
    let seed = 7;
    let cycle = 600usize;
    let rt = OpdRuntime::load(None).map(Rc::new).ok();
    let np = catalog::iot_anomaly();
    println!("pipeline: {} ({})", np.spec.name, np.description);
    for (i, t) in np.spec.tasks.iter().enumerate() {
        let names: Vec<&str> = t.variants.iter().map(|v| v.name.as_str()).collect();
        println!("  stage {i}: {} [{}]", t.name, names.join(", "));
    }

    let trace = Trace::new(
        "steady-high",
        WorkloadGen::new(WorkloadKind::SteadyHigh, seed).trace(cycle + 1),
    );
    println!("\nsteady-high load ≈ {:.0} req/s on a 30-core edge cluster\n", 120.0);
    println!("{:<8} {:>9} {:>10} {:>10} {:>8}", "agent", "avg QoS", "avg cost", "reward", "clamped");

    let mut final_config = None;
    for kind in AgentKind::all() {
        let mut env = Env::from_trace(
            catalog::iot_anomaly().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            &trace,
            make_env_predictor(&rt),
            10,
            3.0,
        );
        let mut agent = make_agent(kind, seed, &rt, None, true).unwrap();
        let res = run_cycle(&mut env, agent.as_mut());
        println!(
            "{:<8} {:>9.3} {:>10.2} {:>10.3} {:>8}",
            res.agent,
            res.avg_qos(),
            res.avg_cost(),
            res.avg_reward(),
            res.clamped
        );
        if kind == AgentKind::Ipa {
            // capture the steady-state config IPA converges to
            let cfg = {
                let obs = env.observe();
                let mut ipa = opd::agents::IpaAgent::new();
                ipa.decide(&obs)
            };
            final_config = Some((env.spec.clone(), cfg));
        }
    }

    if let Some((spec, cfg)) = final_config {
        println!("\nIPA steady-state deployment @ ~120 req/s:");
        for (t, c) in spec.tasks.iter().zip(&cfg) {
            println!(
                "  {:<16} variant={:<12} replicas={} batch={:>2}  ({:.1} cores)",
                t.name,
                t.variants[c.variant].name,
                c.replicas,
                c.batch(),
                c.cores(t)
            );
        }
        println!("  total cores: {:.1} / 30", spec.total_cores(&cfg));
    }
}
