//! Edge video-analytics scenario (the paper's motivating workload class):
//! a 4-stage decode → detect → classify → track pipeline under a fluctuating
//! diurnal load with bursts, comparing all four decision algorithms on the
//! SAME recorded trace (the Fig. 4b/5b protocol).
//!
//! Run: cargo run --release --example edge_video_analytics

use std::rc::Rc;

use opd::cli::{make_agent, make_env_predictor};
use opd::cluster::ClusterTopology;
use opd::config::AgentKind;
use opd::pipeline::{catalog, QosWeights};
use opd::runtime::OpdRuntime;
use opd::sim::{run_cycle, Env};
use opd::util::stats;
use opd::workload::{Trace, WorkloadGen, WorkloadKind};

fn main() {
    let seed = 2024;
    let cycle = 600usize;
    let rt = OpdRuntime::load(None).map(Rc::new).ok();
    if rt.is_none() {
        println!("(no artifacts — OPD runs on the native mirror with init params)");
    }

    // record one trace so all algorithms see identical arrivals
    let trace = Trace::new(
        "fluctuating",
        WorkloadGen::new(WorkloadKind::Fluctuating, seed).trace(cycle + 1),
    );
    println!(
        "video-analytics, fluctuating load: mean {:.1} req/s, peak {:.1} req/s, {cycle}s cycle\n",
        stats::mean(&trace.rates),
        stats::max(&trace.rates)
    );
    println!(
        "{:<8} {:>9} {:>10} {:>10} {:>12} {:>9}",
        "agent", "avg QoS", "avg cost", "reward", "decide(ms)", "restarts"
    );

    for kind in AgentKind::all() {
        let mut env = Env::from_trace(
            catalog::video_analytics().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            &trace,
            make_env_predictor(&rt),
            10,
            3.0,
        );
        let mut agent = make_agent(kind, seed, &rt, None, true).unwrap();
        let res = run_cycle(&mut env, agent.as_mut());
        println!(
            "{:<8} {:>9.3} {:>10.2} {:>10.3} {:>12.3} {:>9}",
            res.agent,
            res.avg_qos(),
            res.avg_cost(),
            res.avg_reward(),
            res.mean_decision_time() * 1e3,
            res.restarts
        );
    }
    println!(
        "\nExpected shape (paper Fig. 4b/5b): greedy cheapest but weak QoS; IPA top \
         QoS at top cost;\nOPD(untrained≈random policy) explores — train it with \
         `opd train` or examples/train_opd to see the balance."
    );
}
