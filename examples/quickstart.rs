//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds the 4-stage video-analytics pipeline on the paper's 3×10-core
//! testbed, drives it with a fluctuating workload for 300 simulated seconds,
//! and lets the OPD agent (AOT HLO policy if `make artifacts` has run,
//! pure-rust mirror otherwise) pick configurations every 10 s.
//!
//! Run: cargo run --release --example quickstart

use std::rc::Rc;

use opd::agents::OpdAgent;
use opd::cluster::ClusterTopology;
use opd::pipeline::{catalog, QosWeights};
use opd::runtime::OpdRuntime;
use opd::sim::{run_cycle, Env};
use opd::workload::predictor::{LoadPredictor, LstmPredictor, MovingMaxPredictor};
use opd::workload::WorkloadKind;

fn main() {
    // 1. pipeline + cluster + workload
    let pipeline = catalog::video_analytics();
    println!("pipeline: {} ({})", pipeline.spec.name, pipeline.description);

    // 2. runtime (AOT HLO) with graceful native fallback. Env predictors
    // are `Send` (DESIGN.md §9), so the LSTM runs through its native mirror
    // on the artifact weights.
    let (mut agent, predictor): (OpdAgent, Box<dyn LoadPredictor + Send>) =
        match OpdRuntime::load(None).map(Rc::new) {
            Ok(rt) => {
                println!("PJRT runtime: {} (AOT HLO decision path)", rt.engine.platform());
                let weights = rt.predictor_weights.clone();
                (OpdAgent::from_runtime(rt, 42), Box::new(LstmPredictor::native(weights)))
            }
            Err(e) => {
                println!("runtime unavailable ({e:#}); using native mirrors");
                let params = vec![0.01f32; opd::nn::spec::POLICY_PARAM_COUNT];
                (OpdAgent::native(params, 42), Box::new(MovingMaxPredictor::default()))
            }
        };
    agent.greedy = true; // evaluation mode: argmax, no exploration

    // 3. environment: 300 s cycle, 10 s adaptation interval
    let mut env = Env::from_workload(
        pipeline.spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        42,
        predictor,
        10,
        300,
        3.0,
    );

    // 4. run one cycle and report
    let res = run_cycle(&mut env, &mut agent);
    println!("\n=== results over {} simulated seconds ===", res.qos_series.len());
    println!("average QoS (Eq. 3)        : {:8.3}", res.avg_qos());
    println!("average cost (Eq. 2, cores): {:8.2}", res.avg_cost());
    println!("average reward (Eq. 7)     : {:8.3}", res.avg_reward());
    println!("decisions                  : {:8}", res.decision_times.len());
    println!(
        "decision time              : {:8.3} ms mean / {:.3} ms total",
        res.mean_decision_time() * 1e3,
        res.total_decision_time() * 1e3
    );
}
