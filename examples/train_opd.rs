//! Train the OPD policy (Algorithm 2: PPO + expert guidance) and evaluate it
//! against the baselines — the full paper loop in one binary.
//!
//! Requires `make artifacts` (training runs through the AOT HLO train step).
//!
//! Run: cargo run --release --example train_opd [-- episodes [envs [sync_every]]]
//!
//! `envs` sets K concurrent rollout lanes (execution-only; default 1) and
//! `sync_every` how many episodes share one parameter snapshot (default =
//! envs; widths > 1 trade update freshness for sampling throughput).

use std::rc::Rc;

use opd::cli::{make_agent, make_env_predictor};
use opd::cluster::ClusterTopology;
use opd::config::AgentKind;
use opd::pipeline::{catalog, QosWeights};
use opd::rl::{Trainer, TrainerConfig};
use opd::runtime::OpdRuntime;
use opd::sim::{run_cycle, Env};
use opd::workload::{Trace, WorkloadGen, WorkloadKind};

fn main() {
    opd::util::logging::init();
    let arg = |n: usize| std::env::args().nth(n).and_then(|s| s.parse::<usize>().ok());
    let episodes = arg(1).unwrap_or(40);
    let envs = arg(2).unwrap_or(1).max(1);
    let sync_every = arg(3).unwrap_or(envs);
    let rt = match OpdRuntime::load(None).map(Rc::new) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("training needs artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };

    // --- train (Algorithm 2) -------------------------------------------
    // reuse_envs off: this factory derives the workload KIND from the seed,
    // so an in-place Env::reset(seed) could not reproduce it (DESIGN.md §9)
    let tcfg = TrainerConfig {
        episodes,
        expert_freq: 4,
        seed: 42,
        reuse_envs: false,
        envs,
        sync_every,
        ..Default::default()
    };
    println!(
        "training OPD: {episodes} episodes (expert every {}th), 400 s episodes, \
         {envs} rollout lane(s), sync every {sync_every}",
        tcfg.expert_freq
    );
    let rt2 = rt.clone();
    let mut trainer = Trainer::new(rt.clone(), tcfg, move |seed| {
        // alternate the training distribution across all three load regimes
        // so the policy learns to adapt (Fig. 4/5 evaluate all three)
        let kind = match seed % 3 {
            0 => WorkloadKind::SteadyLow,
            1 => WorkloadKind::Fluctuating,
            _ => WorkloadKind::SteadyHigh,
        };
        Env::from_workload(
            catalog::video_analytics().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            kind,
            seed,
            make_env_predictor(&Some(rt2.clone())),
            10,
            400,
            3.0,
        )
    });
    trainer.train().expect("training failed");
    trainer.save_checkpoint("opd_checkpoint.bin").unwrap();
    trainer.history.save("opd_training_history.json").unwrap();
    println!("saved opd_checkpoint.bin + opd_training_history.json");

    // --- evaluate vs baselines on a held-out trace ----------------------
    let eval_seed = 999;
    let trace = Trace::new(
        "eval",
        WorkloadGen::new(WorkloadKind::Fluctuating, eval_seed).trace(601),
    );
    println!("\nevaluation on held-out fluctuating trace (600 s):");
    println!("{:<8} {:>9} {:>10} {:>10}", "agent", "avg QoS", "avg cost", "objective");
    for kind in AgentKind::all() {
        let mut env = Env::from_trace(
            catalog::video_analytics().spec,
            ClusterTopology::paper_testbed(),
            QosWeights::default(),
            &trace,
            make_env_predictor(&Some(rt.clone())),
            10,
            3.0,
        );
        let params = if kind == AgentKind::Opd { Some("opd_checkpoint.bin") } else { None };
        let mut agent = make_agent(kind, eval_seed, &Some(rt.clone()), params, true).unwrap();
        let res = run_cycle(&mut env, agent.as_mut());
        let w = QosWeights::default();
        let objective = res.avg_qos() - w.lambda * res.avg_cost() / w.cost_scale;
        println!(
            "{:<8} {:>9.3} {:>10.2} {:>10.3}",
            res.agent,
            res.avg_qos(),
            res.avg_cost(),
            objective
        );
    }
}
