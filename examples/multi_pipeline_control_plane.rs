//! Multi-pipeline control-plane driver: boots an *empty* leader, then drives
//! the v1 REST API the way an operator (or `opd apply`) would — two
//! pipelines deployed onto the shared 30-core cluster, an agent hot-swap,
//! cluster accounting, a delete — all over real HTTP, no PJRT required.
//!
//! Run: cargo run --release --example multi_pipeline_control_plane

use std::sync::Arc;

use opd::cluster::ClusterTopology;
use opd::serve::{
    http_delete, http_get, http_post, v1_router, ControlPlane, HttpServer, Leader, TenantFactory,
};
use opd::util::json::Json;

fn main() {
    opd::util::logging::init();
    let cp = Arc::new(ControlPlane::new());
    let (mut leader, tx) = Leader::new(
        cp.clone(),
        ClusterTopology::paper_testbed(),
        3.0,
        TenantFactory::native(),
    );
    let server = HttpServer::start("127.0.0.1:0", v1_router(&cp, tx), 4).expect("bind leader");
    let addr = server.addr;
    println!("leader control plane: http://{addr}\n");

    let client = std::thread::spawn(move || {
        let post = |path: &str, body: &str| http_post(&addr, path, body).expect("http");
        let get = |path: &str| http_get(&addr, path).expect("http");

        let (code, body) = post(
            "/v1/pipelines",
            r#"{"name":"vid","pipeline":"video-analytics","workload":"steady-high","agent":"greedy","seed":42}"#,
        );
        println!("POST /v1/pipelines vid          → {code}");
        assert_eq!(code, 201, "{body}");
        let (code, _) = post(
            "/v1/pipelines",
            r#"{"name":"iot","pipeline":"iot-anomaly","workload":"steady-low","agent":"ipa","seed":7}"#,
        );
        println!("POST /v1/pipelines iot          → {code}");
        assert_eq!(code, 201);

        // let the shared loop serve both for a while
        std::thread::sleep(std::time::Duration::from_millis(500));

        let (code, _) = post("/v1/pipelines/vid/agent", r#"{"agent":"ipa"}"#);
        println!("POST /v1/pipelines/vid/agent    → {code} (greedy → ipa hot-swap)");
        assert_eq!(code, 200);

        let (code, body) = get("/v1/cluster");
        assert_eq!(code, 200);
        let cl = Json::parse(&body).expect("cluster json");
        println!(
            "GET  /v1/cluster                → {code}: used {:.1} / {:.0} cores across {} pipelines",
            cl.req_f64("used").unwrap(),
            cl.req_f64("capacity").unwrap(),
            cl.get("pipelines").unwrap().as_arr().unwrap().len()
        );

        let (code, body) = get("/v1/pipelines/vid");
        assert_eq!(code, 200);
        let s = Json::parse(&body).expect("status json");
        println!(
            "GET  /v1/pipelines/vid          → {code}: agent={} gen={} avg_qos={:.3} avg_cost={:.1}",
            s.req_str("agent").unwrap(),
            s.get("generation").unwrap().as_i64().unwrap(),
            s.req_f64("avg_qos").unwrap(),
            s.req_f64("avg_cost").unwrap()
        );

        let (code, _) = http_delete(&addr, "/v1/pipelines/iot").expect("http");
        println!("DEL  /v1/pipelines/iot          → {code}");
        assert_eq!(code, 200);

        let (code, _) = post("/v1/shutdown", "");
        println!("POST /v1/shutdown               → {code}");
        assert_eq!(code, 200);
    });

    leader.run(); // single-threaded sim loop; returns on /v1/shutdown
    client.join().unwrap();
    println!(
        "\nOK: {} pipeline(s) still deployed at t={:.0}s of shared-cluster serving.",
        leader.env.n_tenants(),
        leader.env.now
    );
    server.shutdown();
}
