//! END-TO-END DRIVER (the repo's required full-system example): proves all
//! three layers compose on a real serving workload.
//!
//!   L1/L2  Pallas-kernel policy + LSTM predictor, AOT-compiled to HLO
//!   L3     rust coordinator: monitoring, cluster API, OPD decisions
//!   serve  HTTP control plane (Prometheus /metrics, JSON /state)
//!
//! Flow: load the AOT runtime → start the leader's HTTP endpoints → run a
//! full 1200 s workload cycle with the OPD agent deciding every 10 s through
//! the HLO policy → scrape the server's own /metrics and /state over TCP →
//! report serving stats (decision latency percentiles, QoS/cost, predictor
//! accuracy) — the numbers EXPERIMENTS.md records.
//!
//! Run: make artifacts && cargo run --release --example serve_cluster

use std::rc::Rc;
use std::sync::Arc;

use opd::agents::{Agent, OpdAgent};
use opd::cluster::ClusterTopology;
use opd::pipeline::{catalog, QosWeights};
use opd::runtime::OpdRuntime;
use opd::serve::{http_get, ControlPlane};
use opd::sim::Env;
use opd::util::json::Json;
use opd::util::stats;
use opd::workload::predictor::LstmPredictor;
use opd::workload::WorkloadKind;

fn main() {
    opd::util::logging::init();
    let rt = match OpdRuntime::load(None).map(Rc::new) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("end-to-end driver needs artifacts: {e:#}\nrun `make artifacts`");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.engine.platform());
    println!("predictor SMAPE (offline eval): {:.2}%", rt.manifest.predictor_smape * 100.0);

    // ---- leader control plane -----------------------------------------
    let cp = Arc::new(ControlPlane::new());
    let server = cp.serve("127.0.0.1:0").expect("bind control plane");
    println!("control plane: http://{}\n", server.addr);
    cp.metrics.describe("opd_qos", "pipeline QoS (Eq. 3)");
    cp.metrics.describe("opd_cost_cores", "pipeline cost (Eq. 2)");

    // ---- environment: paper protocol (1200 s cycle, 10 s interval) ----
    let mut env = Env::from_workload(
        catalog::video_analytics().spec,
        ClusterTopology::paper_testbed(),
        QosWeights::default(),
        WorkloadKind::Fluctuating,
        42,
        Box::new(LstmPredictor::native(rt.predictor_weights.clone())),
        10,
        1200,
        3.0,
    );
    // trained checkpoint if present, else the AOT init params
    let mut agent = OpdAgent::from_runtime(rt.clone(), 42);
    if let Ok(p) = opd::runtime::read_params(
        std::path::Path::new("opd_checkpoint.bin"),
        opd::nn::spec::POLICY_PARAM_COUNT,
    ) {
        println!("loaded trained checkpoint opd_checkpoint.bin");
        agent.set_params(p);
        agent.greedy = true;
    }

    // ---- serve the cycle ----------------------------------------------
    let wall = std::time::Instant::now();
    let mut decision_ms: Vec<f64> = Vec::new();
    let mut qos_all: Vec<f64> = Vec::new();
    let mut cost_all: Vec<f64> = Vec::new();
    let mut pred_pairs: Vec<(f64, Vec<f64>)> = Vec::new(); // (prediction, future window)
    while !env.done() {
        let t0 = std::time::Instant::now();
        let action = {
            let obs = env.observe();
            cp.series.record("load", obs.load_now);
            cp.series.record("load_pred", obs.load_pred);
            pred_pairs.push((obs.load_pred, Vec::new()));
            agent.decide(&obs)
        };
        decision_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let step = env.step(&action);
        // backfill actuals for predictor scoring (the 10 s we just simulated)
        if let Some(last) = pred_pairs.last_mut() {
            last.1 = step.load_series.clone();
        }
        qos_all.extend_from_slice(&step.qos_series);
        cost_all.extend_from_slice(&step.cost_series);
        for (q, c) in step.qos_series.iter().zip(&step.cost_series) {
            cp.series.record("qos", *q);
            cp.series.record("cost", *c);
        }
        cp.metrics.set_gauge("opd_qos", &[], step.qos);
        cp.metrics.set_gauge("opd_cost_cores", &[], step.cost);
        cp.metrics.inc("opd_decisions_total", &[], 1.0);
        cp.metrics.observe("opd_decision_seconds", &[], decision_ms.last().unwrap() / 1e3);
        cp.publish_state(
            Json::obj()
                .set("t", env.elapsed())
                .set("qos", step.qos)
                .set("cost", step.cost)
                .set("load", *step.load_series.last().unwrap()),
        );
    }
    let wall_s = wall.elapsed().as_secs_f64();

    // ---- prove the serving layer answers over real TCP -----------------
    let (code, metrics_body) = http_get(&server.addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let (code, state_body) = http_get(&server.addr, "/state").unwrap();
    assert_eq!(code, 200);
    let (code, _) = http_get(&server.addr, "/series?name=qos&n=60").unwrap();
    assert_eq!(code, 200);

    // ---- predictor online SMAPE (vs max of each following interval) ----
    let preds: Vec<f64> = pred_pairs.iter().map(|(p, _)| *p).collect();
    let actuals: Vec<f64> =
        pred_pairs.iter().map(|(_, w)| w.iter().copied().fold(0.0, f64::max)).collect();
    let online_smape = stats::smape(&preds, &actuals);

    println!("=== end-to-end serving report (1200 s cycle, 120 decisions) ===");
    println!("wall-clock total              : {wall_s:9.2} s  ({:.0}× real time)", 1200.0 / wall_s);
    println!("avg QoS (Eq. 3)               : {:9.3}", stats::mean(&qos_all));
    println!("avg cost (Eq. 2, cores)       : {:9.2}", stats::mean(&cost_all));
    println!("decision latency p50 / p95    : {:9.3} / {:.3} ms",
        stats::percentile(&decision_ms, 50.0),
        stats::percentile(&decision_ms, 95.0));
    println!("decision throughput           : {:9.1} decisions/s (hot path)",
        1e3 / stats::mean(&decision_ms));
    println!("LSTM online SMAPE             : {:9.2}%", online_smape * 100.0);
    println!("/metrics bytes                : {:9}", metrics_body.len());
    println!("/state sample                 : {}", state_body.replace('\n', " "));
    assert!(metrics_body.contains("opd_decisions_total 120"));
    server.shutdown();
    println!("\nOK: L1 (Pallas) → L2 (JAX/HLO) → L3 (rust) → HTTP all composed.");
}
